"""shard_map row-sharded 2D DWT: halo-exchange correctness on a CPU mesh.

Runs in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
(same pattern as test_distributed.py) so pytest's own process keeps its
single-device world.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.kernels.sharded import check_shardable

ROOT = Path(__file__).resolve().parents[1]


def _run(body: str, n_devices: int = 8) -> str:
    code = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"\n'
        "import sys\n"
        f'sys.path.insert(0, {str(ROOT / "src")!r})\n' + textwrap.dedent(body)
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=540
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


@pytest.mark.slow
@pytest.mark.sharded
def test_sharded_fwd_inv_bit_exact_on_cpu_mesh():
    """4-way row sharding, both modes, multi-level, odd width, batch."""
    out = _run(
        """
        import numpy as np, jax.numpy as jnp
        from repro import kernels as K
        from repro.core import lifting
        from repro.kernels.sharded import check_shardable
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((4, 2), ("data", "model"))
        rng = np.random.default_rng(11)
        checked = 0
        for mode in ("paper", "jpeg2000"):
            for lead in ((), (2,)):
                for (h, w) in ((64, 32), (64, 33), (96, 48), (64, 3)):
                    for levels in (1, 2, 3):
                        try:
                            check_shardable(h, w, 4, levels)
                        except ValueError:
                            continue
                        x = jnp.asarray(
                            rng.integers(-900, 900, lead + (h, w)), jnp.int32
                        )
                        want = lifting.dwt53_fwd_2d_multi(x, levels=levels, mode=mode)
                        got = K.dwt53_fwd_2d_sharded(x, mesh, levels=levels, mode=mode)
                        assert np.array_equal(np.asarray(got.ll), np.asarray(want.ll))
                        for gl, wl in zip(got.details, want.details):
                            for g, w_ in zip(gl, wl):
                                assert np.array_equal(np.asarray(g), np.asarray(w_))
                        xr = K.dwt53_inv_2d_sharded(got, mesh, mode=mode)
                        assert np.array_equal(np.asarray(xr), np.asarray(x))
                        checked += 1
        print("OK", checked)
        """
    )
    assert "OK" in out and int(out.split()[-1]) >= 20


@pytest.mark.slow
@pytest.mark.sharded
def test_sharded_output_stays_sharded():
    """Bands come back row-sharded (no silent all-gather of the result)."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro import kernels as K
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((4,), ("data",))
        x = jnp.asarray(np.arange(64 * 16).reshape(64, 16), jnp.int32)
        pyr = K.dwt53_fwd_2d_sharded(x, mesh, levels=2)
        n_shards = len({d for d in pyr.ll.devices()})
        assert n_shards == 4, pyr.ll.sharding
        print("OK", n_shards)
        """
    )
    assert "OK 4" in out


@pytest.mark.slow
@pytest.mark.sharded
def test_sharded_per_scheme_bit_exact_on_cpu_mesh():
    """Scheme-derived halo exchange: haar ships no halo rows, 97m ships
    4 per direction — both bit-exact vs the single-device reference."""
    out = _run(
        """
        import numpy as np, jax.numpy as jnp
        from repro import kernels as K
        from repro.core import lifting
        from repro.kernels.sharded import check_shardable
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((4,), ("data",))
        rng = np.random.default_rng(23)
        checked = 0
        for scheme in ("haar", "97m"):
            for (h, w, levels) in ((64, 32, 2), (64, 33, 1), (96, 24, 2)):
                check_shardable(h, w, 4, levels, scheme)
                x = jnp.asarray(rng.integers(-900, 900, (h, w)), jnp.int32)
                want = lifting.dwt_fwd_2d_multi(
                    x, levels=levels, scheme=scheme
                )
                got = K.dwt_fwd_2d_sharded(
                    x, mesh, levels=levels, scheme=scheme
                )
                assert np.array_equal(np.asarray(got.ll), np.asarray(want.ll))
                for gl, wl in zip(got.details, want.details):
                    for g, w_ in zip(gl, wl):
                        assert np.array_equal(np.asarray(g), np.asarray(w_))
                xr = K.dwt_inv_2d_sharded(got, mesh, scheme=scheme)
                assert np.array_equal(np.asarray(xr), np.asarray(x))
                checked += 1
        print("OK", checked)
        """,
        n_devices=4,
    )
    assert "OK" in out and int(out.split()[-1]) >= 6


def test_check_shardable_rejects_bad_shapes():
    with pytest.raises(ValueError, match="divisible"):
        check_shardable(60, 32, 4, 2)  # 60 % (4*4) != 0
    with pytest.raises(ValueError, match="W >= 3"):
        check_shardable(64, 2, 4, 1)
    with pytest.raises(ValueError, match="W >= 3"):
        check_shardable(128, 5, 4, 3)  # width hits 2 at level 3
    with pytest.raises(ValueError, match="levels"):
        check_shardable(64, 32, 4, 0)
    check_shardable(64, 32, 4, 2)  # and a valid one passes


@pytest.mark.slow
@pytest.mark.sharded
def test_spatial_2d_pod_sync_converges_to_mean():
    """The spatial_2d gradient codec inside shard_map: per-band ring sums
    + pmax'd shifts reconstruct ~the cross-pod mean for matrix leaves."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.train.grad_compress import WaveletSyncConfig, pod_sync_tree
        from repro.launch.mesh import make_mesh_compat
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:
            shard_map = jax.shard_map
        mesh = make_mesh_compat((2,), ("pod",))
        rng = np.random.default_rng(5)
        grads = {"w": jnp.asarray(rng.normal(size=(2, 64, 96)), jnp.float32),
                 "skinny": jnp.asarray(rng.normal(size=(2, 2, 4096)), jnp.float32),
                 "v": jnp.asarray(rng.normal(size=(2, 8000)), jnp.float32)}
        err = {"w": jnp.zeros((64, 96), jnp.float32),
               "skinny": jnp.zeros((2, 4096), jnp.float32),
               "v": jnp.zeros((8000,), jnp.float32)}
        cfg = WaveletSyncConfig(levels=2, codec="bands", n_pods=2,
                                min_size=256, spatial_2d=True)
        f = shard_map(lambda g, e: pod_sync_tree(g, e, cfg, axis_name="pod"),
                      mesh=mesh, in_specs=(P("pod"), P()),
                      out_specs=(P(), P()), check_rep=False)
        synced, new_err = jax.jit(f)(grads, err)
        for k, g in grads.items():
            want = np.mean(np.asarray(g), axis=0)
            got = np.asarray(synced[k])
            rel = np.linalg.norm(got - want) / np.linalg.norm(want)
            assert rel < 0.05, (k, rel)
            assert np.isfinite(np.asarray(new_err[k])).all(), k
        print("OK")
        """,
        n_devices=2,
    )
    assert "OK" in out


@pytest.mark.slow
@pytest.mark.sharded
def test_spatial_3d_pod_sync_converges_to_mean():
    """The spatial_3d gradient codec inside shard_map: volume-shaped
    leaves route through the fused 3D pyramid (kernels/fused3d.py),
    per-band ring sums + pmax'd shifts reconstruct ~the cross-pod mean,
    and matrix/vector leaves still fall through to the 2D/1D codecs."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.train.grad_compress import WaveletSyncConfig, pod_sync_tree
        from repro.launch.mesh import make_mesh_compat
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:
            shard_map = jax.shard_map
        mesh = make_mesh_compat((2,), ("pod",))
        rng = np.random.default_rng(7)
        grads = {"act": jnp.asarray(rng.normal(size=(2, 6, 16, 24)), jnp.float32),
                 "w": jnp.asarray(rng.normal(size=(2, 64, 96)), jnp.float32),
                 "v": jnp.asarray(rng.normal(size=(2, 8000)), jnp.float32)}
        err = {"act": jnp.zeros((6, 16, 24), jnp.float32),
               "w": jnp.zeros((64, 96), jnp.float32),
               "v": jnp.zeros((8000,), jnp.float32)}
        cfg = WaveletSyncConfig(levels=2, codec="bands", n_pods=2,
                                min_size=256, spatial_3d=True, spatial_2d=True)
        f = shard_map(lambda g, e: pod_sync_tree(g, e, cfg, axis_name="pod"),
                      mesh=mesh, in_specs=(P("pod"), P()),
                      out_specs=(P(), P()), check_rep=False)
        synced, new_err = jax.jit(f)(grads, err)
        for k, g in grads.items():
            want = np.mean(np.asarray(g), axis=0)
            got = np.asarray(synced[k])
            rel = np.linalg.norm(got - want) / np.linalg.norm(want)
            assert rel < 0.05, (k, rel)
            assert np.isfinite(np.asarray(new_err[k])).all(), k
        print("OK")
        """,
        n_devices=2,
    )
    assert "OK" in out
