"""Fused N-D engine (kernels/fused3d.py): bit-exactness vs the oracle on
every path — whole-volume Pallas kernel, depth-slab kernel, XLA
reference — for every registered scheme, both rounding modes, odd and
degenerate shapes, batched lead dims, and the ndim=1/2 re-wrapping."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import kernels as K
from repro.core import lifting as L
from repro.kernels import fused3d

RNG = np.random.default_rng(11)
SCHEMES = K.available_schemes()


def _vol(*shape):
    return jnp.asarray(RNG.integers(-2048, 2048, shape), jnp.int32)


def _assert_pyr_equal(got: L.PyramidND, want: L.PyramidND):
    np.testing.assert_array_equal(np.asarray(got.approx), np.asarray(want.approx))
    assert len(got.details) == len(want.details)
    for lvl_g, lvl_w in zip(got.details, want.details):
        assert len(lvl_g) == len(lvl_w)
        for bg, bw in zip(lvl_g, lvl_w):
            np.testing.assert_array_equal(np.asarray(bg), np.asarray(bw))


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize(
    "shape", [(2, 2, 2), (3, 3, 3), (2, 3, 4), (5, 6, 7), (8, 8, 8)]
)
def test_roundtrip_matches_reference(shape, scheme):
    """Default-backend fwd matches the oracle; inverse restores exactly."""
    x = _vol(*shape)
    levels = L.max_levels_nd(shape)
    for mode in ("paper", "jpeg2000"):
        want = L.dwt_fwd_nd(x, levels=levels, mode=mode, scheme=scheme, ndim=3)
        got = K.dwt_fwd_nd(x, levels=levels, mode=mode, scheme=scheme, ndim=3)
        _assert_pyr_equal(got, want)
        xr = K.dwt_inv_nd(got, mode=mode, scheme=scheme)
        np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


@pytest.mark.parametrize("shape", [(1, 4, 4), (4, 1, 4), (4, 4, 1), (1, 1, 1)])
def test_degenerate_axes_identity_pyramid(shape):
    """An axis of length 1 admits no level: max_levels_nd is 0 and the
    levels=0 pyramid round-trips as the identity (no crash)."""
    assert L.max_levels_nd(shape) == 0
    x = _vol(*shape)
    pyr = K.dwt_fwd_nd(x, levels=0, ndim=3)
    assert pyr.details == ()
    np.testing.assert_array_equal(
        np.asarray(K.dwt_inv_nd(pyr)), np.asarray(x)
    )
    with pytest.raises(ValueError, match="too small"):
        K.dwt_fwd_nd(x, levels=1, ndim=3)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_interpret_whole_volume_kernel(scheme):
    """backend="interpret" runs the whole-volume Pallas kernel body."""
    x = _vol(4, 6, 8)
    want = L.dwt_fwd_nd(x, levels=1, scheme=scheme, ndim=3)
    got = K.dwt_fwd_nd(x, levels=1, scheme=scheme, ndim=3, backend="interpret")
    _assert_pyr_equal(got, want)
    xr = K.dwt_inv_nd(got, scheme=scheme, backend="interpret")
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("shape", [(8, 5, 6), (9, 4, 4), (12, 6, 5)])
def test_forced_slab_path(monkeypatch, scheme, shape):
    """REPRO_DWT_SLAB forces the depth-slab kernel on small volumes (the
    multi-slab grid lever); schemes that cannot window the depth axis
    (cdf22 anywhere, haar on odd depth) stay whole-volume — either way
    the result is bit-exact vs the oracle."""
    monkeypatch.setenv("REPRO_DWT_SLAB", "4")
    plan = fused3d.plan_3d(*shape, backend="interpret", scheme=scheme)
    can_window_depth = K.get_scheme(scheme).can_window(shape[0])
    assert plan == (
        "slab-interpret" if can_window_depth else "whole-interpret"
    ), plan
    x = _vol(*shape)
    for mode in ("paper", "jpeg2000"):
        want = L.dwt_fwd_nd(x, levels=2, mode=mode, scheme=scheme, ndim=3)
        got = K.dwt_fwd_nd(
            x, levels=2, mode=mode, scheme=scheme, ndim=3, backend="interpret"
        )
        _assert_pyr_equal(got, want)
        xr = K.dwt_inv_nd(got, mode=mode, scheme=scheme, backend="interpret")
        np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


def test_ndim_routing_matches_existing_engines():
    """ndim=1/2 reuse the fused 1D/2D engines; the PyramidND wrapping
    must agree band-for-band with the oracle's code order."""
    x2 = _vol(12, 14)
    got2 = K.dwt_fwd_nd(x2, levels=2, ndim=2)
    _assert_pyr_equal(got2, L.dwt_fwd_nd(x2, levels=2, ndim=2))
    np.testing.assert_array_equal(np.asarray(K.dwt_inv_nd(got2)), np.asarray(x2))

    x1 = _vol(64)
    got1 = K.dwt_fwd_nd(x1, levels=3, ndim=1)
    _assert_pyr_equal(got1, L.dwt_fwd_nd(x1, levels=3, ndim=1))
    np.testing.assert_array_equal(np.asarray(K.dwt_inv_nd(got1)), np.asarray(x1))

    x4 = _vol(4, 4, 4, 4)
    got4 = K.dwt_fwd_nd(x4, levels=1, ndim=4)
    _assert_pyr_equal(got4, L.dwt_fwd_nd(x4, levels=1, ndim=4))
    np.testing.assert_array_equal(np.asarray(K.dwt_inv_nd(got4)), np.asarray(x4))


def test_batched_lead_dims_map_to_grid():
    x = _vol(3, 6, 8, 8)  # (batch, D, H, W)
    got = K.dwt_fwd_nd(x, levels=2, ndim=3)
    _assert_pyr_equal(got, L.dwt_fwd_nd(x, levels=2, ndim=3))
    np.testing.assert_array_equal(np.asarray(K.dwt_inv_nd(got)), np.asarray(x))


def test_narrow_dtypes_promote():
    """int8/int16 volumes compute in int32 (no silent wraparound)."""
    for dtype in (jnp.int8, jnp.int16):
        x = jnp.asarray(RNG.integers(100, 124, (4, 4, 4)), dtype)
        got = K.dwt_fwd_nd(x, levels=1, ndim=3)
        _assert_pyr_equal(got, L.dwt_fwd_nd(x, levels=1, ndim=3))
        np.testing.assert_array_equal(
            np.asarray(K.dwt_inv_nd(got)), np.asarray(x, np.int32)
        )


def test_pack_unpack_nd_roundtrip():
    shape = (5, 6, 7)
    x = _vol(*shape)
    pyr = K.dwt_fwd_nd(x, levels=2, ndim=3)
    flat = K.pack_nd(pyr)
    assert flat.shape == (5 * 6 * 7,)
    back = K.unpack_nd(flat, shape, 2)
    _assert_pyr_equal(back, pyr)
    # levels=0 needs an explicit ndim (no bands to derive it from)
    p0 = K.dwt_fwd_nd(x, levels=0, ndim=3)
    with pytest.raises(ValueError, match="ndim"):
        K.pack_nd(p0)
    np.testing.assert_array_equal(
        np.asarray(K.unpack_nd(K.pack_nd(p0, ndim=3), shape, 0).approx),
        np.asarray(p0.approx),
    )


def test_band_shapes_nd_matches_transform():
    shape = (6, 7, 9)
    a_shape, det_shapes = K.band_shapes_nd(shape, 2)
    pyr = K.dwt_fwd_nd(_vol(*shape), levels=2, ndim=3)
    assert tuple(pyr.approx.shape) == a_shape
    for lvl, want_lvl in zip(pyr.details, det_shapes):
        assert tuple(tuple(b.shape) for b in lvl) == want_lvl


def test_max_levels_nd_loops_are_safe():
    for shape in [(1, 8, 8), (2, 2, 2), (3, 5, 9), (16, 16, 16)]:
        lv = K.max_levels_nd(shape)
        pyr = K.dwt_fwd_nd(_vol(*shape), levels=lv, ndim=3)  # must not raise
        assert pyr.levels == lv


def test_inv_rejects_malformed_pyramid():
    # odd dims: the detail bands have distinct shapes, so swapping in a
    # wrong-shaped band is detectable (on even dims all octants coincide)
    pyr = K.dwt_fwd_nd(_vol(5, 6, 7), levels=1, ndim=3)
    bad = L.PyramidND(
        approx=pyr.approx,
        details=((pyr.details[0][0],) * 7,),  # every band shaped like code 1
    )
    with pytest.raises(ValueError, match="band shape mismatch"):
        K.dwt_inv_nd(bad)
    short = L.PyramidND(approx=pyr.approx, details=(pyr.details[0][:5],))
    with pytest.raises(ValueError):
        K.dwt_inv_nd(short)


def test_plan_3d_names_paths(monkeypatch):
    """plan_3d mirrors plan_2d: explicit pallas requests degrade to
    interpret off-accelerator, tiny budgets force the slab path, and
    un-slab-able volumes past the budget name the xla cliff."""
    assert fused3d.plan_3d(4, 8, 8, backend="xla") == "xla"
    assert fused3d.plan_3d(4, 8, 8, backend="pallas").endswith(
        "-pallas" if K.has_compiled_pallas() else "-interpret"
    )
    monkeypatch.setenv("REPRO_DWT_VMEM_MB", "0.01")
    # 17x16x16 = 4352 elems exceeds the floored 4096-elem budget -> must
    # leave whole-volume; cdf53 can slab the depth axis, cdf22 cannot
    # (antisymmetric lift is unwindowable) -> the named xla cliff
    kind = "pallas" if K.has_compiled_pallas() else "interpret"
    assert (
        fused3d.plan_3d(17, 16, 16, backend="pallas", scheme="cdf53")
        == f"slab-{kind}"
    )
    assert fused3d.plan_3d(17, 16, 16, backend="pallas", scheme="cdf22") == "xla"


def test_levels_validation():
    x = _vol(4, 4, 4)
    with pytest.raises(ValueError):
        K.dwt_fwd_nd(x, levels=-1, ndim=3)
    with pytest.raises(ValueError):
        K.dwt_fwd_nd(x, levels=1, ndim=0)
    with pytest.raises(ValueError):
        K.dwt_fwd_nd(_vol(4, 4), levels=1, ndim=3)  # too few axes
