"""Tests for repro.obs: metrics, events, tracing, subsystem wiring.

Covers the histogram quantile contract (bucketed p50/p99 must bracket
the exact numpy percentile on adversarial distributions — property
tested), counter thread-safety under concurrent hammering and the serve
retry path, the degrade-counting fix (every degrade counts, the warning
still fires once), warning-site consolidation (categories preserved),
and the end-to-end acceptance check: one seeded run reports live
metrics from all five subsystems plus a valid Chrome trace.
"""
from __future__ import annotations

import json
import threading
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - shim container
    from hypothesis_shim import given, settings
    from hypothesis_shim import strategies as st

from repro import obs
from repro.obs.metrics import Histogram, MetricRegistry


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Each test starts from empty process-wide metrics/events/spans."""
    obs.set_enabled(True)
    obs.reset()
    yield
    obs.reset()


# ---------------------------------------------------------------------------
# Metric registry basics.
# ---------------------------------------------------------------------------


def test_counter_monotonic_and_labelled_series_are_distinct():
    a = obs.counter("t.hits", route="a")
    b = obs.counter("t.hits", route="b")
    a.inc()
    a.inc(2.0)
    b.inc()
    assert a.value == 3.0 and b.value == 1.0
    assert obs.counter("t.hits", route="a") is a  # get-or-create
    with pytest.raises(ValueError):
        a.inc(-1)


def test_gauge_set_and_add():
    g = obs.gauge("t.depth")
    g.set(5)
    g.add(-2)
    assert g.value == 3.0


def test_metric_kind_mismatch_raises():
    obs.counter("t.thing")
    with pytest.raises(TypeError, match="already registered"):
        obs.gauge("t.thing")


def test_snapshot_keys_and_histogram_summary():
    obs.counter("t.c", k="v").inc()
    h = obs.histogram("t.h")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = obs.registry.snapshot()
    assert snap['t.c{k="v"}'] == 1.0
    s = snap["t.h"]
    assert s["count"] == 3 and s["sum"] == 6.0 and s["min"] == 1.0
    assert s["max"] == 3.0 and "p50" in s and "p99" in s


def test_prometheus_exposition_shape():
    obs.counter("t.total", op="enc").inc(4)
    obs.gauge("t.depth").set(2)
    h = obs.histogram("t.lat", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    text = obs.render_prometheus()
    assert '# TYPE t_total counter' in text
    assert 't_total{op="enc"} 4' in text
    assert '# TYPE t_depth gauge' in text
    assert '# TYPE t_lat histogram' in text
    # cumulative bucket counts, then the +Inf bucket == count
    assert 't_lat_bucket{le="1"} 1' in text
    assert 't_lat_bucket{le="10"} 2' in text
    assert 't_lat_bucket{le="+Inf"} 3' in text
    assert 't_lat_sum 55.5' in text
    assert 't_lat_count 3' in text


def test_disabled_flag_makes_instruments_no_ops():
    c = obs.counter("t.c")
    h = obs.histogram("t.h")
    with obs.disabled():
        c.inc(100)
        h.observe(1.0)
        obs.emit(obs.Event(subsystem="t"))
        with obs.span("t.s", subsystem="t"):
            pass
    assert c.value == 0.0
    assert h.count == 0
    assert obs.events.total == 0
    assert obs.tracer.total == 0


# ---------------------------------------------------------------------------
# Histogram quantile math: bucketed estimates must bracket the exact
# sample percentile (property-tested on adversarial distributions).
# ---------------------------------------------------------------------------


def _adversarial_data(kind: str, seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "lognormal":  # heavy right tail across many decades
        return rng.lognormal(0.0, 3.0, n)
    if kind == "constant":  # every observation in ONE bucket
        return np.full(n, 7.3)
    if kind == "bimodal":  # two spikes five decades apart
        return np.where(rng.integers(2, size=n) == 0, 1e-2, 1e3).astype(float)
    if kind == "uniform-wide":
        return rng.uniform(1e-3, 1e6, n)
    if kind == "tiny":  # below the smallest default bucket bound
        return rng.uniform(1e-5, 5e-4, n)
    raise AssertionError(kind)


@settings(max_examples=30)
@given(
    kind=st.sampled_from(
        ["lognormal", "constant", "bimodal", "uniform-wide", "tiny"]
    ),
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.integers(min_value=1, max_value=400),
)
def test_quantile_bounds_bracket_exact_percentiles(kind, seed, n):
    data = _adversarial_data(kind, seed, n)
    reg = MetricRegistry()
    h = reg.histogram("q.h")
    for v in data:
        h.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        lo, hi = h.quantile_bounds(q)
        exact = float(np.percentile(data, q * 100))
        # numpy interpolates between order statistics; the bucketed
        # bounds cover the nearest-rank order statistic, so allow the
        # bounds to be checked against the un-interpolated quantile too
        nearest = float(np.sort(data)[min(n - 1, max(0, int(np.ceil(q * n)) - 1))])
        assert lo <= nearest <= hi, (kind, q, lo, nearest, hi)
        assert lo <= max(exact, lo) and min(exact, hi) <= hi
        est = h.quantile(q)
        assert lo <= est <= hi, (kind, q, lo, est, hi)


def test_quantile_estimate_brackets_numpy_on_large_sample():
    data = np.random.default_rng(0).lognormal(1.0, 2.0, 5000)
    h = Histogram("q.h", (), threading.Lock())
    for v in data:
        h.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        lo, hi = h.quantile_bounds(q)
        assert lo <= float(np.percentile(data, q * 100)) <= hi


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError, match="ascend"):
        Histogram("bad", (), threading.Lock(), buckets=(3.0, 1.0))


def test_empty_histogram_quantiles_are_zero():
    h = Histogram("e", (), threading.Lock())
    assert h.quantile(0.5) == 0.0
    assert h.quantile_bounds(0.99) == (0.0, 0.0)


# ---------------------------------------------------------------------------
# Thread safety: counters hammered concurrently, and via the serve
# retry path (worker threads submitting through a faulted engine).
# ---------------------------------------------------------------------------


def test_counter_thread_safety_under_contention():
    c = obs.counter("t.contended")
    h = obs.histogram("t.contended_h")
    n_threads, n_incs = 8, 2000

    def hammer():
        for i in range(n_incs):
            c.inc()
            h.observe(float(i % 50))

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == float(n_threads * n_incs)
    assert h.count == n_threads * n_incs


def test_serve_retry_path_counts_attempts_and_events():
    from repro.resilience import inject
    from repro.resilience.errors import RetryWarning
    from repro.serve.engine import TransformRequest, WaveletServeEngine

    eng = WaveletServeEngine(
        height=16, width=16, levels=1, batch_slots=2, retry_backoff_s=0.001
    )
    img = np.random.default_rng(1).integers(-100, 100, (16, 16), np.int32)
    eng.submit(TransformRequest(uid=1, image=img))
    with inject.armed("serve.transform", times=1):
        with pytest.warns(RetryWarning, match="retrying"):
            done = eng.step()
    assert done[0].done
    assert obs.registry.counter("serve.retry_attempts").value == 1.0
    assert len(obs.events.query(obs.RetryEvent)) == 1
    # the retry that then succeeded is a heal
    heals = obs.events.query(obs.HealEvent, subsystem="serve")
    assert len(heals) == 1 and heals[0].mechanism == "retry"


# ---------------------------------------------------------------------------
# Satellite 1: every degrade counts; the warning still fires once.
# ---------------------------------------------------------------------------


def test_repeat_degrades_count_every_occurrence_warn_once():
    from repro.kernels import backend

    reason = "test-only: repeat-degrade counting"  # unique key this run
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for _ in range(5):
            backend.note_degrade("pallas", "xla", reason)
    ours = [x for x in w if reason in str(x.message)]
    assert len(ours) == 1, "dedupe must keep the warning once-per-key"
    assert isinstance(ours[0].message, backend.BackendDegradeWarning)
    c = obs.registry.counter(
        "kernels.degrades", requested="pallas", resolved="xla"
    )
    assert c.value == 5.0, "every degrade occurrence must count"
    evs = [
        e for e in obs.events.query(obs.DegradeEvent, subsystem="kernels")
        if e.reason == reason
    ]
    assert len(evs) == 5


# ---------------------------------------------------------------------------
# Satellite 2: consolidated warning sites keep their categories.
# ---------------------------------------------------------------------------


def test_encode_degrade_warning_category_and_event():
    from repro.resilience import inject
    from repro.resilience.errors import ResilienceWarning
    from repro.serve.engine import TransformRequest, WaveletServeEngine

    eng = WaveletServeEngine(
        height=16, width=16, levels=1, batch_slots=2, encode_response=True
    )
    img = np.random.default_rng(2).integers(-100, 100, (16, 16), np.int32)
    eng.submit(TransformRequest(uid=7, image=img))
    with inject.armed("serve.encode_batch", times=1):
        with pytest.warns(ResilienceWarning, match="degrading to per-request"):
            done = eng.step()
    assert done[0].encoded is not None  # per-request fallback served bytes
    degr = obs.events.query(obs.DegradeEvent, subsystem="serve")
    assert len(degr) == 1 and degr[0].requested == "batch-encode"
    assert obs.registry.counter("serve.encode_degrades").value == 1.0


def test_warn_event_emits_both_event_and_warning():
    with pytest.warns(RuntimeWarning, match="both channels"):
        obs.warn_event(
            obs.FaultEvent(subsystem="serve", error="X", site="t"),
            RuntimeWarning("both channels"),
        )
    assert len(obs.events.query(obs.FaultEvent)) == 1


# ---------------------------------------------------------------------------
# Event log semantics.
# ---------------------------------------------------------------------------


def test_event_ring_bounded_total_unbounded():
    log = obs.EventLog(capacity=8)
    for i in range(20):
        log.emit(obs.Event(subsystem="t", detail=str(i)))
    assert len(log) == 8
    assert log.total == 20
    assert [e.detail for e in log][0] == "12"  # oldest 12 fell off


def test_event_query_filters_and_to_dict():
    obs.emit(obs.DegradeEvent(subsystem="kernels", requested="a"))
    obs.emit(obs.FaultEvent(subsystem="serve", error="E", site="s"))
    assert len(obs.events.query(obs.DegradeEvent)) == 1
    assert len(obs.events.query(subsystem="serve")) == 1
    d = obs.events.query(obs.FaultEvent)[0].to_dict()
    assert d["kind"] == "FaultEvent" and d["error"] == "E"
    assert obs.events.counts() == {"DegradeEvent": 1, "FaultEvent": 1}


# ---------------------------------------------------------------------------
# Tracing and Chrome-trace export.
# ---------------------------------------------------------------------------


def test_span_records_duration_and_attrs():
    with obs.span("t.work", subsystem="serve", bucket="16x16"):
        pass
    (s,) = obs.tracer.spans(name="t.work")
    assert s.cat == "serve" and s.dur_us >= 0.0
    assert s.args == {"bucket": "16x16"}


def test_chrome_trace_is_valid_and_loadable_shape(tmp_path):
    with obs.span("a", subsystem="codec"):
        with obs.span("b", subsystem="codec"):
            pass
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(path)
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == 2
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"
        assert {"name", "cat", "ts", "dur", "pid", "tid"} <= set(ev)
    # inner span nests inside the outer on the same lane
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    a, b = by_name["a"], by_name["b"]
    assert a["ts"] <= b["ts"] and b["ts"] + b["dur"] <= a["ts"] + a["dur"] + 1


def test_span_exceptions_still_record():
    with pytest.raises(RuntimeError):
        with obs.span("t.fail", subsystem="serve"):
            raise RuntimeError("boom")
    assert len(obs.tracer.spans(name="t.fail")) == 1


# ---------------------------------------------------------------------------
# End-to-end acceptance: one seeded run covers all five subsystems.
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_all_five_subsystems_report_in_one_run(tmp_path):
    import jax

    from repro.ckpt.checkpoint import CheckpointManager
    from repro.serve.engine import TransformRequest, WaveletServeEngine

    from repro import kernels as K

    rng = np.random.default_rng(0)
    # a direct (un-jitted) kernel call records the kernels-subsystem span
    K.dwt_fwd_2d_multi(
        rng.integers(-100, 100, (1, 16, 16), dtype=np.int32)[:], levels=1
    )
    eng = WaveletServeEngine(
        buckets=[(32, 32)], batch_slots=4, levels=2, encode_response=True
    )
    done = eng.run([
        TransformRequest(
            uid=i, image=rng.integers(-100, 100, (32, 32), dtype=np.int32)
        )
        for i in range(6)
    ])
    assert all(r.done for r in done)

    mgr = CheckpointManager(tmp_path / "ckpt", codec="wz-rice")
    mgr.save(0, {"w": rng.normal(size=(16, 16)).astype(np.float32)})
    mgr.restore()

    if len(jax.devices()) >= 2:
        from jax.sharding import Mesh

        from repro.kernels import sharded

        mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
        x = rng.integers(-50, 50, (16, 32), dtype=np.int32)
        sharded.dwt_inv_2d_sharded(
            sharded.dwt_fwd_2d_sharded(jax.numpy.asarray(x), mesh, levels=1),
            mesh, timeout_s=30.0,
        )
        want = {"kernels", "codec", "serve", "ckpt", "collectives"}
    else:  # single-device CI lane: no collectives to observe
        want = {"kernels", "codec", "serve", "ckpt"}

    assert want <= obs.subsystems(), obs.subsystems()
    snap = obs.snapshot()
    assert snap["events"]["total"] > 0
    cats = {e["cat"] for e in obs.export_chrome_trace()["traceEvents"]}
    assert want <= cats, cats
