"""Data pipeline + serving engine tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, FileTokens, Prefetcher, SyntheticLM, WaveletBandSplit
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve.serve_step import Request, ServeEngine


def test_synthetic_determinism():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=9)
    a = SyntheticLM(cfg).batch(3)
    b = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # different steps differ
    c = SyntheticLM(cfg).batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synthetic_host_sharding_partition():
    """Two hosts' shards concatenate to the single-host global batch."""
    g = DataConfig(vocab_size=100, seq_len=16, global_batch=4, n_hosts=1, host_id=0)
    h0 = DataConfig(vocab_size=100, seq_len=16, global_batch=4, n_hosts=2, host_id=0)
    h1 = DataConfig(vocab_size=100, seq_len=16, global_batch=4, n_hosts=2, host_id=1)
    full = SyntheticLM(g).batch(5)["tokens"]
    part = np.concatenate([SyntheticLM(h0).batch(5)["tokens"], SyntheticLM(h1).batch(5)["tokens"]])
    np.testing.assert_array_equal(full, part)


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)


def test_file_tokens(tmp_path):
    arr = np.arange(1000, dtype=np.uint16) % 50
    path = tmp_path / "toks.npy"
    np.save(path, arr)
    cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2)
    src = FileTokens(cfg, path)
    b = src.batch(0)
    np.testing.assert_array_equal(b["tokens"][0], arr[:16].astype(np.int32))
    np.testing.assert_array_equal(b["labels"][0], arr[1:17].astype(np.int32))


def test_wavelet_band_split_stage():
    stage = WaveletBandSplit(levels=2)
    x = np.random.default_rng(0).integers(0, 255, size=(4, 64))
    out = stage(x)
    assert out["approx"].shape == (4, 16)
    assert out["detail_0"].shape == (4, 16)
    assert out["detail_1"].shape == (4, 32)


def test_prefetcher():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
    pf = Prefetcher(SyntheticLM(cfg))
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    assert (s0, s1) == (0, 1)
    pf.close()


def test_serve_engine_end_to_end():
    cfg = reduced(get_config("granite-3-8b"))
    params = L.init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, prefill_len=8)
    reqs = [
        Request(uid=1, prompt=np.array([5, 6, 7], np.int32), max_new=4),
        Request(uid=2, prompt=np.array([9, 3], np.int32), max_new=3),
        Request(uid=3, prompt=np.array([2], np.int32), max_new=2),
    ]
    done = eng.run(reqs, max_steps=50)
    assert len(done) == 3
    for r in done:
        assert len(r.out_tokens) >= r.max_new
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_serve_prefill_compiles_once(monkeypatch):
    """Admitting N requests must trace/compile prefill exactly once.

    The engine jits ``T.prefill`` in ``__post_init__`` (fixed prompt
    length => one static shape); a per-admit ``jax.jit(lambda ...)``
    would retrace on every call because each lambda is a fresh callable.
    Counting invocations of the traced function catches a regression:
    under jit, the Python body runs only while tracing.
    """
    calls = {"n": 0}
    real_prefill = T.prefill

    def counting_prefill(*args, **kwargs):
        calls["n"] += 1
        return real_prefill(*args, **kwargs)

    monkeypatch.setattr(T, "prefill", counting_prefill)
    cfg = reduced(get_config("stablelm-1.6b"))
    params = L.init_params(T.model_defs(cfg), jax.random.PRNGKey(2))
    eng = ServeEngine(cfg, params, batch_slots=4, prefill_len=8)
    for uid in range(3):
        eng.admit(Request(uid=uid, prompt=np.array([1 + uid, 2], np.int32), max_new=1))
    assert calls["n"] == 1, f"prefill traced {calls['n']}x for 3 admits"


def test_serve_greedy_deterministic():
    cfg = reduced(get_config("stablelm-1.6b"))
    params = L.init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, batch_slots=1, prefill_len=8)
        done = eng.run([Request(uid=1, prompt=np.array([4, 4, 4], np.int32), max_new=5)])
        outs.append(tuple(done[0].out_tokens))
    assert outs[0] == outs[1]


def test_wavelet_serve_engine_batched():
    """The 2D transform serving engine: micro-batched fused dispatches."""
    from repro.core import lifting
    from repro.serve.serve_step import TransformRequest, WaveletServeEngine

    rng = np.random.default_rng(41)
    eng = WaveletServeEngine(
        height=32, width=48, batch_slots=4, levels=2, backend="interpret"
    )
    reqs = [
        TransformRequest(uid=i, image=rng.integers(0, 255, (32, 48)).astype(np.int32))
        for i in range(7)
    ]
    done = eng.run(reqs)
    assert len(done) == 7 and all(r.done for r in done)
    # last request (in the second, partially-filled micro-batch) is exact
    want = lifting.dwt53_fwd_2d_multi(jnp.asarray(reqs[6].image, jnp.int32), levels=2)
    np.testing.assert_array_equal(np.asarray(done[6].pyramid.ll), np.asarray(want.ll))
    for got_lvl, want_lvl in zip(done[6].pyramid.details, want.details):
        for g, w in zip(got_lvl, want_lvl):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_wavelet_serve_engine_rejects_wrong_bucket():
    """Images larger than every bucket are rejected at admission;
    smaller images zero-pad into the nearest containing bucket."""
    from repro.serve.serve_step import TransformRequest, WaveletServeEngine

    eng = WaveletServeEngine(height=16, width=16, batch_slots=2, levels=1)
    with pytest.raises(ValueError, match="bucket"):
        eng.submit(TransformRequest(uid=1, image=np.zeros((32, 32), np.int32)))
    eng.submit(TransformRequest(uid=2, image=np.zeros((8, 8), np.int32)))
    assert eng.scheduler.pending() == 1  # pad-admitted, not rejected


def test_wavelet_serve_volume_route():
    """A depth-configured engine serves (D, H, W) volume buckets through
    the fused N-D engine and returns per-request PyramidND slices."""
    from repro import kernels as K
    from repro.core import lifting
    from repro.serve.serve_step import TransformRequest, WaveletServeEngine

    rng = np.random.default_rng(12)
    eng = WaveletServeEngine(
        height=16, width=16, depth=4, batch_slots=2, levels=1,
        backend="interpret",
    )
    reqs = [
        TransformRequest(uid=i, image=rng.integers(0, 255, (4, 16, 16)).astype(np.int32))
        for i in range(3)
    ]
    done = eng.run(reqs)
    assert len(done) == 3 and all(r.done for r in done)
    for r in done:
        want = lifting.dwt_fwd_nd(jnp.asarray(r.image), levels=1, ndim=3)
        np.testing.assert_array_equal(
            np.asarray(r.pyramid.approx), np.asarray(want.approx)
        )
    # bucket validation: 2D images are rejected on a volume engine
    with pytest.raises(ValueError, match="bucket"):
        eng.submit(TransformRequest(uid=9, image=np.zeros((16, 16), np.int32)))
    # the sharded mesh route stays 2D-only
    with pytest.raises(ValueError, match="2D-only"):
        WaveletServeEngine(height=16, width=16, depth=4, mesh=object(), levels=1)
