"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values. (Full configs are exercised only via
the dry-run — ShapeDtypeStruct, no allocation.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import layers as L
from repro.models import transformer as T
from repro.train import optim
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64, key=KEY):
    if cfg.input_mode == "tokens":
        return {
            "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
    return {
        "embeds": jax.random.normal(key, (b, s, cfg.d_model), jnp.float32),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }


@pytest.fixture(scope="module")
def states():
    return {}


def _params_for(cfg):
    return L.init_params(T.model_defs(cfg), KEY)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact(arch):
    """The exact assigned config values survive in the registry."""
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    expected = {
        "granite-34b": dict(n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
                            d_ff=24576, vocab_size=49152),
        "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
                             d_ff=12800, vocab_size=49155),
        "stablelm-1.6b": dict(n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
                              d_ff=5632, vocab_size=100352),
        "nemotron-4-340b": dict(n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
                                d_ff=73728, vocab_size=256000),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, vocab_size=32064),
        "llama4-maverick-400b-a17b": dict(n_layers=48, d_model=5120, n_heads=40,
                                          n_kv_heads=8, vocab_size=202048),
        "rwkv6-7b": dict(n_layers=32, d_model=4096, d_ff=14336, vocab_size=65536),
        "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                                  d_ff=7680, vocab_size=256000),
        "musicgen-medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                d_ff=6144, vocab_size=2048),
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
                              d_ff=16384, vocab_size=92553),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params = _params_for(cfg)
    batch = _batch(cfg)
    logits, aux = T.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds")
    )
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    params = _params_for(cfg)
    opt = optim.adamw_init(params)
    step = jax.jit(make_train_step(cfg, optim.AdamWConfig(lr=1e-3)))
    batch = _batch(cfg)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l[0] - l[1]).sum()),
        jax.tree_util.tree_map(lambda a, b: (a, b), new_params, params),
        0.0,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = _params_for(cfg)
    caches = T.init_caches(cfg, 2, 16)
    caches["len"] = jnp.asarray(4, jnp.int32)
    if cfg.input_mode == "tokens":
        logits, nc = T.decode_step(params, cfg, caches, tokens=jnp.ones((2, 1), jnp.int32))
    else:
        logits, nc = T.decode_step(
            params, cfg, caches, embeds=jnp.ones((2, 1, cfg.d_model), jnp.float32)
        )
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(nc["len"]) == 5


def test_moe_capacity_and_dispatch():
    from repro.configs.base import MoEConfig
    from repro.models.moe import apply_moe, capacity, moe_defs

    moe = MoEConfig(n_experts=4, experts_per_token=2, d_ff_expert=32, capacity_factor=2.0)
    defs = moe_defs(16, moe)
    params = L.init_params(defs, KEY)
    x = jax.random.normal(KEY, (2, 32, 16))
    out, aux = apply_moe(params, x, moe)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert capacity(32, moe) == 32


def test_rwkv_chunked_equals_scan():
    """The chunk-parallel RWKV path must match the sequential oracle."""
    from repro.models import rwkv6 as R

    b, s, h, hd = 2, 64, 2, 8
    key = KEY
    r = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, hd)))
    lw = jnp.clip(lw, R.LOG_W_MIN, -1e-4)
    u = jax.random.normal(jax.random.fold_in(key, 4), (h, hd))
    s0 = jnp.zeros((b, h, hd, hd))
    o1, st1 = R.timemix_scan(r, k, v, lw, u, s0)
    o2, st2 = R.timemix_chunked(r, k, v, lw, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_sequential():
    from repro.models.rglru import lru_scan

    b, s, w = 2, 33, 8
    log_a = -jnp.abs(jax.random.normal(KEY, (b, s, w))) - 0.01
    u = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, w))
    h0 = jax.random.normal(jax.random.fold_in(KEY, 2), (b, w))
    h_par, h_last = lru_scan(log_a, u, h0)
    # sequential reference
    h = h0
    outs = []
    for t in range(s):
        h = jnp.exp(log_a[:, t]) * h + u[:, t] if t > 0 else jnp.exp(log_a[:, 0]) * h0 + u[:, 0]
        outs.append(h)
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq), rtol=1e-5, atol=1e-5)


def test_hybrid_layer_pattern():
    """26 layers -> 8 (rec,rec,attn) super-layers + 2 trailing rec."""
    cfg = get_config("recurrentgemma-2b")
    n_super, n_tail = T.hybrid_layout(cfg)
    assert n_super == 8 and n_tail == 2
    assert n_super * 3 + n_tail == cfg.n_layers


def test_decode_matches_forward_dense():
    """Prefill+decode must agree with the full forward (teacher forcing)."""
    cfg = reduced(get_config("granite-3-8b"))
    params = _params_for(cfg)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits_full, _ = T.forward(params, cfg, tokens=toks, for_training=False)
    _, caches = T.prefill(params, cfg, tokens=toks[:, : s - 1])
    logits_dec, _ = T.decode_step(params, cfg, caches, tokens=toks[:, s - 1 :])
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_dec[:, 0], np.float32),
        rtol=2e-2, atol=2e-2,
    )
