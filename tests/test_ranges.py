"""Certified integer range safety (core.ranges) and checked mode.

Four layers under test:

1. the DERIVATION — exact interval tracing of the lifting cascade, with
   differential sweeps asserting every band value an engine actually
   produces lies inside the traced interval, and that certificates sit
   exactly on the safe/unsafe boundary (nothing is hardcoded per
   scheme);
2. the CHECKED EXECUTION MODE — ``checked=True`` / ``REPRO_DWT_CHECKED``
   on every engine (oracle 1D/2D/N-D, fused 1D/2D/3D, tiled, sharded)
   raises :class:`IntegerOverflowError` for wrap-capable inputs and is
   bit-exact and silent on certified inputs;
3. the ADVERSARIAL EXTREMES — int32 ``iinfo.min``/``iinfo.max`` samples
   through every engine must either round-trip bit-exactly (modular
   lifting is still invertible) or raise the typed error, never return
   a silently-mismatched reconstruction;
4. the BOUNDARIES — codec encode, checkpoint wavelet codecs, gradient
   quantization and serve admission all consult the certificates.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro import kernels as K
from repro.core import lifting as L
from repro.core import ranges
from repro.resilience.errors import IntegerOverflowError, ResilienceError

SCHEMES = ("cdf53", "haar", "cdf22", "97m")
MODES = ("paper", "jpeg2000")
I32 = np.iinfo(np.int32)


@pytest.fixture(autouse=True)
def _neutral_checked_env(monkeypatch):
    """Pin the env toggle off so every test states its own checked mode.

    The CI chaos lane exports ``REPRO_DWT_CHECKED=1`` over this file;
    the default-off assertions (wraparound tolerated, boundaries silent)
    must stay deterministic under it.  Tests that exercise the env
    toggle re-set it explicitly via monkeypatch.
    """
    monkeypatch.delenv("REPRO_DWT_CHECKED", raising=False)


def _rand(shape, lo, hi, seed=0, dtype=np.int32):
    return jnp.asarray(
        np.random.default_rng(seed).integers(lo, hi + 1, shape), dtype
    )


# ---------------------------------------------------------------------------
# Derivation: traced intervals bound reality; certificates are exact.
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(
    scheme=st.sampled_from(SCHEMES),
    mode=st.sampled_from(MODES),
    levels=st.integers(1, 3),
    mag_bits=st.integers(0, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_trace_forward_bounds_actual_bands_1d(scheme, mode, levels, mag_bits, seed):
    """Differential sweep: every band value of real data lies inside the
    interval trace of that data's hull — the soundness contract."""
    mag = 1 << mag_bits
    x = _rand((2, 32), -mag, mag, seed)
    ft = ranges.trace_forward(
        scheme, levels, ranges.Interval(-mag, mag), mode=mode, ndim=1
    )
    pyr = L.dwt_fwd(x, levels=levels, mode=mode, scheme=scheme)
    a = np.asarray(pyr.approx)
    assert ft.approx.lo <= a.min() and a.max() <= ft.approx.hi
    # lifting.WaveletPyramid stores details coarsest-first; trace level
    # order is outermost-first, so index from the other end
    for lvl, band in enumerate(reversed(pyr.details)):
        b = np.asarray(band)
        iv = ft.details[lvl][0]
        assert iv.lo <= b.min() and b.max() <= iv.hi


@settings(max_examples=10)
@given(
    scheme=st.sampled_from(SCHEMES),
    mode=st.sampled_from(MODES),
    seed=st.integers(0, 2**31 - 1),
)
def test_trace_forward_bounds_actual_bands_2d(scheme, mode, seed):
    mag = 4096
    x = _rand((2, 16, 16), -mag, mag, seed)
    ft = ranges.trace_forward(
        scheme, 2, ranges.Interval(-mag, mag), mode=mode, ndim=2
    )
    pyr = L.dwt_fwd_2d_multi(x, levels=2, mode=mode, scheme=scheme)
    hull = ft.band_hull()
    for band in jax.tree_util.tree_leaves(pyr):
        b = np.asarray(band)
        assert hull.lo <= b.min() and b.max() <= hull.hi


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_certificate_is_exact_boundary(scheme, ndim):
    """cert.hi is the LARGEST safe magnitude: the trace at the bound fits
    the compute dtype and the trace one past it does not (unless the
    whole dtype range is safe) — proof the value is derived, not guessed."""
    cert = ranges.range_certificate(scheme, 2, np.int32, ndim=ndim)
    ext = ranges.cascade_extremes(
        scheme, 2, ranges.Interval(cert.lo, cert.hi), ndim=ndim
    )
    assert I32.min <= ext.lo and ext.hi <= I32.max
    if cert.hi < I32.max:
        ext2 = ranges.cascade_extremes(
            scheme, 2, ranges.Interval(-(cert.hi + 1), cert.hi + 1), ndim=ndim
        )
        assert ext2.lo < I32.min or ext2.hi > I32.max


def test_certificates_shrink_with_levels_and_ndim():
    for scheme in SCHEMES:
        c1 = ranges.range_certificate(scheme, 1, np.int32)
        c2 = ranges.range_certificate(scheme, 2, np.int32)
        c3 = ranges.range_certificate(scheme, 3, np.int32)
        assert c1.hi >= c2.hi >= c3.hi > 0
        d1 = ranges.range_certificate(scheme, 1, np.int32, ndim=2)
        d2 = ranges.range_certificate(scheme, 1, np.int32, ndim=3)
        assert c1.hi >= d1.hi >= d2.hi > 0


def test_certified_levels_consistent_with_certificates():
    for scheme in SCHEMES:
        cert = ranges.range_certificate(scheme, 2, np.int32, ndim=2)
        n = ranges.certified_levels(
            scheme, np.int32, (cert.lo, cert.hi), ndim=2
        )
        assert n >= 2
        # one past the certified bound must certify strictly fewer levels
        if cert.hi < I32.max:
            m = ranges.certified_levels(
                scheme, np.int32, (-(cert.hi + 1), cert.hi + 1), ndim=2
            )
            assert m < 2
    # out-of-dtype input certifies nothing
    assert ranges.certified_levels("cdf53", np.int32, (0, 2**40)) == 0


def test_narrow_dtypes_always_certify_deep_pyramids():
    """int16-range data in int32 compute has >= 5 cdf53 levels of room —
    the paper's 8-bit-sample regime never needs a headroom thought."""
    for scheme in ("cdf53", "haar"):
        assert ranges.certified_levels(scheme, np.int16, (-32768, 32767)) >= 5
    cert = ranges.range_certificate("cdf53", 3, np.int16)
    assert cert.hi == 32767  # whole dtype certified: compute is int32


def test_trace_inverse_and_band_safe_input():
    ft = ranges.trace_forward("cdf53", 2, ranges.Interval(-1000, 1000), ndim=2)
    it = ranges.trace_inverse(
        "cdf53", 2, ft.approx, ft.details, ndim=2
    )
    # inverse of the traced bands contains the original input interval
    assert it.approx.lo <= -1000 and 1000 <= it.approx.hi
    # band_safe_input: bands provably fit int16 at the derived magnitude
    m = ranges.band_safe_input("cdf53", 2, 32767, mode="paper", ndim=1)
    bh = ranges.trace_forward(
        "cdf53", 2, ranges.Interval(-m, m), mode="paper"
    ).band_hull()
    assert -32767 <= bh.lo and bh.hi <= 32767
    bh2 = ranges.trace_forward(
        "cdf53", 2, ranges.Interval(-(m + 1), m + 1), mode="paper"
    ).band_hull()
    assert bh2.lo < -32767 or bh2.hi > 32767


# ---------------------------------------------------------------------------
# Checked execution mode, every engine.
# ---------------------------------------------------------------------------


def _oracle_1d(x, checked=None):
    pyr = L.dwt_fwd(x, levels=2, scheme="cdf53", checked=checked)
    return L.dwt_inv(pyr, scheme="cdf53", checked=checked)


def _oracle_2d(x, checked=None):
    pyr = L.dwt_fwd_2d_multi(x, levels=2, scheme="cdf53", checked=checked)
    return L.dwt_inv_2d_multi(pyr, scheme="cdf53", checked=checked)


def _oracle_nd(x, checked=None):
    pyr = L.dwt_fwd_nd(x, levels=2, scheme="cdf53", ndim=3, checked=checked)
    return L.dwt_inv_nd(pyr, scheme="cdf53", checked=checked)


def _fused_1d(x, checked=None):
    pyr = K.dwt_fwd(x, levels=2, scheme="cdf53", checked=checked)
    return K.dwt_inv(pyr, scheme="cdf53", checked=checked)


def _fused_2d(x, checked=None):
    pyr = K.dwt_fwd_2d_multi(x, levels=2, scheme="cdf53", checked=checked)
    return K.dwt_inv_2d_multi(pyr, scheme="cdf53", checked=checked)


def _fused_3d(x, checked=None):
    pyr = K.dwt_fwd_nd(x, levels=2, scheme="cdf53", ndim=3, checked=checked)
    return K.dwt_inv_nd(pyr, scheme="cdf53", checked=checked)


ENGINES_2D_SHAPE = (2, 16, 16)
ENGINES = [
    ("oracle-1d", _oracle_1d, (2, 32)),
    ("oracle-2d", _oracle_2d, ENGINES_2D_SHAPE),
    ("oracle-nd", _oracle_nd, (8, 8, 8)),
    ("fused-1d", _fused_1d, (2, 32)),
    ("fused-2d", _fused_2d, ENGINES_2D_SHAPE),
    ("fused-3d", _fused_3d, (8, 8, 8)),
]


@pytest.mark.parametrize("name,roundtrip,shape", ENGINES)
def test_checked_mode_rejects_wraparound(name, roundtrip, shape):
    x = jnp.full(shape, I32.max, jnp.int32)
    with pytest.raises(IntegerOverflowError):
        roundtrip(x, checked=True)


@pytest.mark.parametrize("name,roundtrip,shape", ENGINES)
def test_checked_mode_certified_inputs_roundtrip(name, roundtrip, shape):
    cert = ranges.range_certificate(
        "cdf53", 2, np.int32, ndim=len(shape) - 1 if len(shape) > 2 else 1
    )
    # samples AT the certified bound: the hardest legal input
    rng = np.random.default_rng(7)
    x = jnp.asarray(
        rng.choice(np.array([cert.lo, 0, cert.hi], np.int64), shape), jnp.int32
    )
    xr = roundtrip(x, checked=True)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


def test_checked_mode_tiled_engine(monkeypatch):
    monkeypatch.setenv("REPRO_DWT_TILE", "8")
    x = jnp.full((1, 16, 16), I32.max, jnp.int32)
    with pytest.raises(IntegerOverflowError):
        K.dwt_fwd_2d_multi(x, levels=2, checked=True)
    ok = _rand((1, 16, 16), -4096, 4096, 3)
    pyr = K.dwt_fwd_2d_multi(ok, levels=2, checked=True)
    xr = K.dwt_inv_2d_multi(pyr, checked=True)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(ok))


def test_checked_mode_sharded_engine():
    from jax.sharding import Mesh

    from repro.kernels import sharded

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    x = jnp.full((16, 16), I32.max, jnp.int32)
    with pytest.raises(IntegerOverflowError):
        sharded.dwt_fwd_2d_sharded(x, mesh, levels=2, checked=True)
    ok = _rand((16, 16), -4096, 4096, 4)
    pyr = sharded.dwt_fwd_2d_sharded(ok, mesh, levels=2, checked=True)
    xr = sharded.dwt_inv_2d_sharded(pyr, mesh, checked=True)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(ok))


def test_checked_mode_measured_not_static():
    """The per-level measured walk admits real data a static full-cascade
    trace would reject: 97m 2D x3 levels at +-4096 is far outside the
    worst-case certificate yet provably safe for actual samples."""
    cert = ranges.range_certificate("97m", 3, np.int32, ndim=2)
    assert cert.hi < 4096  # static worst case genuinely excludes this
    x = _rand((1, 32, 32), -4096, 4096, 5)
    pyr = L.dwt_fwd_2d_multi(x, levels=3, scheme="97m", checked=True)
    xr = L.dwt_inv_2d_multi(pyr, scheme="97m", checked=True)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


def test_checked_inverse_rejects_hostile_bands():
    """Bands that are NOT the forward image of any in-range input (e.g.
    a foreign bitstream) make the inverse wrap; the checked inverse
    post-verifies the reconstruction and raises instead of returning it."""
    hp = L.WaveletPyramid(
        approx=jnp.full((1, 8), I32.max, jnp.int32),
        details=(
            jnp.full((1, 8), I32.max, jnp.int32),
            jnp.full((1, 16), I32.max, jnp.int32),
        ),
    )
    with pytest.raises(IntegerOverflowError):
        L.dwt_inv(hp, checked=True)
    with pytest.raises(IntegerOverflowError):
        K.dwt_inv(hp, checked=True)


def test_env_toggle_and_kwarg_precedence(monkeypatch):
    x = jnp.full((1, 32), I32.max, jnp.int32)
    monkeypatch.setenv("REPRO_DWT_CHECKED", "1")
    with pytest.raises(IntegerOverflowError):
        L.dwt_fwd(x, levels=1)
    # explicit kwarg wins over the env toggle
    pyr = L.dwt_fwd(x, levels=1, checked=False)
    assert pyr.approx.dtype == jnp.int32
    monkeypatch.setenv("REPRO_DWT_CHECKED", "0")
    L.dwt_fwd(x, levels=1)  # off: silent (wrapping) compute, as ever
    monkeypatch.delenv("REPRO_DWT_CHECKED")
    L.dwt_fwd(x, levels=1)  # default: off


def test_disabled_path_never_traces(monkeypatch):
    """checked=False is one predicate: no interval machinery may run."""

    def boom(*a, **kw):  # noqa: ARG001
        raise AssertionError("trace ran on the disabled path")

    monkeypatch.setattr(ranges, "trace_forward", boom)
    monkeypatch.setattr(ranges, "_check_cascade", boom)
    x = _rand((2, 32), -4096, 4096, 6)
    for _name, roundtrip, shape in ENGINES:
        y = _rand(shape, -1024, 1024, 8)
        np.testing.assert_array_equal(
            np.asarray(roundtrip(y)), np.asarray(y)
        )


def test_overflow_error_is_typed():
    err = None
    try:
        L.dwt_fwd(jnp.full((1, 32), I32.max, jnp.int32), levels=1, checked=True)
    except IntegerOverflowError as e:
        err = e
    assert isinstance(err, OverflowError)
    assert isinstance(err, ResilienceError)
    assert "certified" in str(err) or "certificate" in str(err).lower() or (
        "range_certificate" in str(err)
    )


# ---------------------------------------------------------------------------
# Adversarial extremes: iinfo edges through every engine.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("val", [I32.min, I32.max])
@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("name,roundtrip,shape", ENGINES[:1] + ENGINES[3:4])
def test_extreme_int32_all_schemes_1d(val, scheme, name, roundtrip, shape):
    """iinfo edges x every scheme x oracle+fused 1D: bit-exact modular
    round-trip or the typed error — never a silent mismatch."""
    x = jnp.full((2, 32), val, jnp.int32)
    try:
        pyr = L.dwt_fwd(x, levels=2, scheme=scheme)
        xr = L.dwt_inv(pyr, scheme=scheme)
    except IntegerOverflowError:
        return
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


@pytest.mark.parametrize("val", [I32.min, I32.max])
@pytest.mark.parametrize("name,roundtrip,shape", ENGINES)
def test_extreme_int32_every_engine(val, name, roundtrip, shape):
    x = jnp.full(shape, val, jnp.int32)
    try:
        xr = roundtrip(x)
    except IntegerOverflowError:
        return
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))
    # and the checked variant must refuse the same input loudly
    with pytest.raises(IntegerOverflowError):
        roundtrip(x, checked=True)


@settings(max_examples=15)
@given(
    scheme=st.sampled_from(SCHEMES),
    val=st.sampled_from([I32.min, I32.max, I32.min + 1, I32.max - 1, 2**30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_extreme_mixed_with_noise_differential(scheme, val, seed):
    """Differential vs the bigint-widened oracle: where the checked mode
    admits data near the edge, the engine result equals the exact
    (non-modular) transform; where it raises, wrapping was possible."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-1024, 1024, (1, 32)).astype(np.int64)
    x[0, rng.integers(0, 32)] = val
    xj = jnp.asarray(x, jnp.int32)
    try:
        pyr = L.dwt_fwd(xj, levels=1, scheme=scheme, checked=True)
    except IntegerOverflowError:
        data = ranges.Interval(int(x.min()), int(x.max()))
        ft = ranges.trace_forward(scheme, 1, data, mode="paper")
        assert ft.lo < I32.min or ft.hi > I32.max
        return
    # admitted: every band must match the exact object-dtype lifting
    ft = ranges.trace_forward(
        scheme,
        1,
        ranges.Interval(int(x.min()), int(x.max())),
        mode="paper",
    )
    assert I32.min <= ft.lo and ft.hi <= I32.max
    xr = L.dwt_inv(pyr, scheme=scheme, checked=True)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(xj))


# ---------------------------------------------------------------------------
# Boundaries: codec, checkpoint, quantize, serve.
# ---------------------------------------------------------------------------


def test_codec_encode_checked_boundary():
    from repro.codec import container

    x = jnp.full((1, 64), 2**30, jnp.int32)
    wrapped = K.dwt_fwd(x, levels=2)
    with pytest.raises(IntegerOverflowError):
        container.encode_pyramid(wrapped, checked=True)
    assert isinstance(container.encode_pyramid(wrapped), bytes)  # off: as ever
    for scheme in SCHEMES:
        good = K.dwt_fwd(_rand((1, 64), -32767, 32767, 9), levels=2, scheme=scheme)
        blob = container.encode_pyramid(good, scheme=scheme, checked=True)
        assert container.decode_pyramid(blob).scheme == scheme


def test_ckpt_wz_quant_limit_certified():
    from repro.ckpt import checkpoint as CK

    # cdf53: the historical heuristic was already safe -> byte-identical
    assert CK._wz_quant_limit(4095.0, "cdf53", 2, 1) == 4095.0
    # 97m: the heuristic lied; the derived limit clamps it
    lim = CK._wz_quant_limit(4095.0, "97m", 2, 1)
    assert 1 <= lim < 4095.0
    bh = ranges.trace_forward(
        "97m", 2, ranges.Interval(-int(lim), int(lim)), mode="paper"
    ).band_hull()
    assert -32767 <= bh.lo and bh.hi <= 32767  # int16 pack provably safe


def test_ckpt_wz_97m_roundtrip_within_bound():
    from repro.ckpt import checkpoint as CK

    arr = np.random.default_rng(11).normal(size=(256,)).astype(np.float32)
    data, meta = CK._encode(arr, "wz", 2, scheme="97m")
    back = CK._decode(data, arr.shape, arr.dtype, "wz", meta)
    assert np.max(np.abs(back - arr)) <= meta["scale"] / 2 + 1e-6


def test_ckpt_wzrice_levels_capped_by_certificate():
    from repro.ckpt import checkpoint as CK

    arr = np.random.default_rng(12).normal(size=(8, 16, 16)).astype(np.float32)
    data, meta = CK._encode(arr, "wz-rice", 3, scheme="97m")
    cap = ranges.certified_levels(
        "97m", np.int32, (-32767, 32767), mode="paper", ndim=3
    )
    assert meta["levels"] <= max(1, cap)
    back = CK._decode(data, arr.shape, arr.dtype, "wz-rice", meta)
    assert np.max(np.abs(back - arr)) <= meta["scale"] / 2 + 1e-6
    # default scheme: cap far above the requested depth, nothing changes
    _, meta2 = CK._encode(arr, "wz-rice", 2, scheme="cdf53")
    assert meta2["levels"] == 2


def test_quantize_certificate_clamp():
    from repro.core import compression as C

    g = jnp.asarray(np.random.default_rng(13).normal(size=512), jnp.float32)
    s = C.tensor_scale(g)
    np.testing.assert_array_equal(
        np.asarray(C.quantize(g, s)),
        np.asarray(C.quantize(g, s, scheme="cdf53", levels=3)),
    )
    q = C.quantize(g, s, scheme="97m", levels=3, ndim=2, mode="jpeg2000")
    cert = ranges.range_certificate("97m", 3, np.int32, mode="jpeg2000", ndim=2)
    assert int(jnp.max(jnp.abs(q))) <= cert.hi


def test_serve_submit_range_admission():
    from repro.serve.serve_step import TransformRequest, WaveletServeEngine

    eng = WaveletServeEngine(
        height=16, width=16, batch_slots=2, levels=2, checked=True
    )
    # a spread interval (constant images trace as degenerate, hence safe)
    hot = np.full((16, 16), 2**29, np.int32)
    hot[::2] = -(2**29)
    with pytest.raises(IntegerOverflowError):
        eng.submit(TransformRequest(uid=0, image=hot))
    assert eng.scheduler.pending() == 0  # shed synchronously, nothing queued
    good = TransformRequest(
        uid=1,
        image=np.random.default_rng(14)
        .integers(-4096, 4096, (16, 16))
        .astype(np.int32),
    )
    eng.submit(good)
    (served,) = eng.run([])
    assert served.done and served.pyramid is not None
    # unchecked engine admits the same hot request (historic behavior)
    eng2 = WaveletServeEngine(height=16, width=16, batch_slots=2, levels=2)
    eng2.submit(TransformRequest(uid=2, image=hot))
    assert eng2.scheduler.pending() == 1


# ---------------------------------------------------------------------------
# Chaos-lane variants: checked mode under the fault-injection invariant
# (typed error or bit-exact — never silent corruption).
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_chaos_checked_env_forces_typed_errors(monkeypatch):
    monkeypatch.setenv("REPRO_DWT_CHECKED", "1")
    hot = jnp.full((2, 16, 16), I32.max, jnp.int32)
    for fwd in (
        lambda: L.dwt_fwd_2d_multi(hot, levels=2),
        lambda: K.dwt_fwd_2d_multi(hot, levels=2),
        lambda: K.dwt_fwd_nd(jnp.full((8, 8, 8), I32.max, jnp.int32), levels=1, ndim=3),
    ):
        with pytest.raises(IntegerOverflowError):
            fwd()
    # and certified traffic flows untouched under the same env
    ok = _rand((2, 16, 16), -4096, 4096, 15)
    pyr = K.dwt_fwd_2d_multi(ok, levels=2)
    np.testing.assert_array_equal(
        np.asarray(K.dwt_inv_2d_multi(pyr)), np.asarray(ok)
    )


@pytest.mark.chaos
def test_chaos_checked_serve_sheds_not_corrupts(monkeypatch):
    from repro.serve.serve_step import TransformRequest, WaveletServeEngine

    monkeypatch.setenv("REPRO_DWT_CHECKED", "1")
    eng = WaveletServeEngine(height=16, width=16, batch_slots=2, levels=2)
    hot = np.full((16, 16), 2**29, np.int32)
    hot[::2] = -(2**29)
    with pytest.raises(IntegerOverflowError):
        eng.submit(TransformRequest(uid=0, image=hot))
