"""Pallas kernel tests: shape/dtype sweep, bit-exact vs the jnp oracle.

The kernel-path sweeps pin ``backend="interpret"`` so the Pallas dataflow
itself is exercised on every platform (CPU default dispatch is the XLA
reference, which would compare the oracle against itself); dispatch-level
behaviour is covered in test_backend_dispatch.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(11)

LENGTHS = [16, 17, 64, 255, 256, 257, 300, 511, 512, 513, 1000, 2048, 2049]
DTYPES = [
    (jnp.int8, -128, 127),
    (jnp.int16, -4096, 4096),
    (jnp.int32, -(2**20), 2**20),
]


@pytest.mark.parametrize("n", LENGTHS)
@pytest.mark.parametrize("mode", ["paper", "jpeg2000"])
def test_fwd_matches_ref(n, mode):
    x = jnp.asarray(RNG.integers(-1000, 1000, size=(3, n)), jnp.int32)
    s, d = ops.dwt53_fwd_1d(x, mode=mode, backend="interpret")
    s_r, d_r = ref.dwt53_fwd_1d(x, mode=mode)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d_r))


@pytest.mark.parametrize("n", LENGTHS)
@pytest.mark.parametrize("mode", ["paper", "jpeg2000"])
def test_inv_roundtrip(n, mode):
    x = jnp.asarray(RNG.integers(-1000, 1000, size=(2, n)), jnp.int32)
    s, d = ops.dwt53_fwd_1d(x, mode=mode, backend="interpret")
    xr = ops.dwt53_inv_1d(s, d, mode=mode, backend="interpret")
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


@pytest.mark.parametrize("dtype,lo,hi", DTYPES)
def test_dtype_sweep(dtype, lo, hi):
    for n in (64, 257, 1024):
        x = jnp.asarray(RNG.integers(lo, hi, size=(4, n)), dtype=dtype)
        s, d = ops.dwt53_fwd_1d(x, backend="interpret")
        s_r, d_r = ref.dwt53_fwd_1d(x.astype(s.dtype))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(d_r))
        xr = ops.dwt53_inv_1d(s, d, backend="interpret")
        np.testing.assert_array_equal(np.asarray(xr), np.asarray(x, dtype=xr.dtype))


@pytest.mark.parametrize("backend", [None, "interpret", "xla"])
def test_narrow_dtypes_promote_to_int32(backend):
    """int8/int16 compute in int32: the predict sum must never wrap."""
    x = jnp.asarray(RNG.integers(-128, 127, size=(2, 64)), jnp.int8)
    s, d = ops.dwt53_fwd_1d(x, backend=backend)
    assert s.dtype == jnp.int32 and d.dtype == jnp.int32
    # the regression shape: int8 [120..123] used to wrap to d = [-128, -127]
    x8 = jnp.asarray([[120, 121, 122, 123] * 16], jnp.int8)
    s8, d8 = ops.dwt53_fwd_1d(x8, backend=backend)
    assert int(jnp.abs(d8).max()) <= 2  # smooth ramp -> tiny details
    np.testing.assert_array_equal(
        np.asarray(ops.dwt53_inv_1d(s8, d8, backend=backend)),
        np.asarray(x8, dtype=np.int32),
    )
    # int16 near the dtype ceiling used to wrap the same way
    x16 = jnp.asarray([[32700, 32701, 32702, 32703] * 16], jnp.int16)
    s16, d16 = ops.dwt53_fwd_1d(x16, backend=backend)
    assert s16.dtype == jnp.int32 and int(jnp.abs(d16).max()) <= 2
    # narrow UNSIGNED ints promote identically (wrapper == oracle)
    xu = jnp.asarray(RNG.integers(0, 255, size=(2, 64)), jnp.uint8)
    su, du = ops.dwt53_fwd_1d(xu, backend=backend)
    su_r, du_r = ref.dwt53_fwd_1d(xu)
    assert su.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(su), np.asarray(su_r))
    np.testing.assert_array_equal(np.asarray(du), np.asarray(du_r))


@pytest.mark.parametrize("backend", [None, "interpret", "xla"])
def test_multilevel_matches_ref(backend):
    """The fused multi-level path matches the per-level oracle exactly."""
    x = jnp.asarray(RNG.integers(0, 255, size=(4, 1000)), jnp.int32)
    pk = ops.dwt53_fwd(x, levels=5, backend=backend)
    pr = ref.dwt53_fwd(x, levels=5)
    np.testing.assert_array_equal(np.asarray(pk.approx), np.asarray(pr.approx))
    for a, b in zip(pk.details, pr.details):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(ops.dwt53_inv(pk, backend=backend)), np.asarray(x)
    )


def test_leading_dims_batched():
    x = jnp.asarray(RNG.integers(0, 255, size=(2, 3, 5, 256)), jnp.int32)
    s, d = ops.dwt53_fwd_1d(x, backend="interpret")
    assert s.shape == (2, 3, 5, 128)
    s_r, d_r = ref.dwt53_fwd_1d(x)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=16, max_value=700),
    rows=st.integers(min_value=1, max_value=5),
    mode=st.sampled_from(["paper", "jpeg2000"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_kernel_equals_oracle(n, rows, mode, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-(2**14), 2**14, size=(rows, n)), jnp.int32)
    s, d = ops.dwt53_fwd_1d(x, mode=mode, backend="interpret")
    s_r, d_r = ref.dwt53_fwd_1d(x, mode=mode)
    assert (s == s_r).all() and (d == d_r).all()
    assert (ops.dwt53_inv_1d(s, d, mode=mode, backend="interpret") == x).all()


def test_kernel_block_boundaries():
    """Values that straddle tile boundaries (block_pairs=256) exactly."""
    n = 4 * 256 * 2  # 4 tiles of pairs
    x = jnp.asarray(np.arange(n, dtype=np.int32)[None] * 3 - 1000)
    s, d = ops.dwt53_fwd_1d(x, backend="interpret")
    s_r, d_r = ref.dwt53_fwd_1d(x)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d_r))
