"""Unit tests for the checked-in CI gate logic (benchmarks/gate.py).

The gate used to live as an untestable heredoc inside smoke.sh; these
fixtures run a known-good payload through every gate (must pass clean)
and then break it one field at a time (each break must produce exactly
the expected failure), so a gate regression is caught in tier-1 instead
of silently green-lighting broken benchmarks.
"""
import copy
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import gate  # noqa: E402


def _good_rows() -> dict:
    rows = {
        "table2.ls.adders": "4.0",
        "table2.ls.shifters": "2.0",
        "table2.ls.multipliers": "0.0",
        "table2.scheme.cdf53.adders": "4.0",
        "table2.scheme.cdf53.shifters": "2.0",
    }
    for name in gate.REQUIRED_SCHEMES:
        rows[f"table2.scheme.{name}.multipliers"] = "0.0"
    return rows


def _good_bench() -> dict:
    scheme_row = {
        "bit_exact": True,
        "multipliers_per_pair": 0,
        "adders_per_pair": 4,
        "shifters_per_pair": 2,
    }
    return {
        "platform": "cpu",
        "default_backend": "xla",
        "bit_exact": True,
        "1d_multilevel": {
            "shape": [8, 16384], "levels": 3,
            "speedup_fused_vs_interpret": 4.0,
        },
        "2d": {"shape": [256, 256], "speedup_fused_vs_interpret": 5.0},
        "2d_large": {
            "shape": [2048, 2048], "plan": "xla", "bit_exact": True,
            "fwd_us": 1.0, "inv_us": 1.0,
        },
        "2d_pyramid": {
            "shape": [2048, 2048], "levels": 3, "bit_exact": True,
            "speedup_fused_vs_per_level": 1.0,
        },
        "2d_batched": {"shape": [16, 256, 256], "levels": 2, "images_per_s": 100.0},
        "schemes": {n: dict(scheme_row) for n in gate.REQUIRED_SCHEMES},
        "3d": {
            "shape": [16, 64, 64], "levels": 2, "plan": "xla",
            "bit_exact": True, "per_axis_us": 8.0, "fused_us": 1.0,
            "speedup_fused_vs_per_axis": 8.0,
            "schemes": {n: {"bit_exact": True, "fwd_us": 1.0}
                        for n in gate.REQUIRED_SCHEMES},
        },
        "3d_large": {"shape": [64, 512, 512], "plan": "xla"},
        "codec": {
            "block": 256,
            "lossless": {n: True for n in gate.REQUIRED_SCHEMES},
            "encode_mbps": 10.0,
            "decode_mbps": 10.0,
            "smooth": {
                "raw_bytes": 196608, "wz_rice_bytes": 20000,
                "zlib_bytes": 60000, "ratio_vs_zlib": 3.0,
            },
            "noisy": {
                "raw_bytes": 196608, "wz_rice_bytes": 90000,
                "zlib_bytes": 180000, "ratio_vs_zlib": 2.0,
            },
        },
        "resilience": {
            "container_bytes": 50000,
            "parity_overhead_bytes": 9000,
            "parity_overhead_ratio": 0.18,
            "single_band_recovery": True,
            "recovery": {
                "bit-flip": "recovered",
                "truncation": "typed-error",
                "save-crash": "previous-intact",
                "pallas-failure": "degraded",
                "stuck-neighbor": "typed-error",
                "deadline-miss": "typed-error",
            },
        },
        "serve": {
            "buckets": [[16, 16], [32, 32]],
            "batch_slots": 8,
            "levels": 2,
            "requests": 32,
            "requests_per_s": 100.0,
            "p99_ms": 50.0,
            "compiles": 2,
            "cache_hit_rate": 1.0,
            "batch_encode_ms": 1.0,
            "per_request_encode_ms": 4.0,
            "batch_encode_speedup": 4.0,
            "thumbnail_bytes_fraction": 0.1,
        },
        "ranges": {
            "certificates": {
                "cdf53": {"safe_abs_1d_l1": gate.CDF53_SAFE_ABS_1D_L1,
                          "safe_abs_2d_l2": 268435455,
                          "growth_bits_1d_l1": 1.0,
                          "int16_levels_3d": 5},
                "haar": {"safe_abs_1d_l1": 1073741823,
                         "safe_abs_2d_l2": 536870911,
                         "growth_bits_1d_l1": 1.0,
                         "int16_levels_3d": 5},
                "cdf22": {"safe_abs_1d_l1": 536870911,
                          "safe_abs_2d_l2": 134217727,
                          "growth_bits_1d_l1": 2.0,
                          "int16_levels_3d": 4},
                "97m": {"safe_abs_1d_l1": 12005499,
                        "safe_abs_2d_l2": 928521,
                        "growth_bits_1d_l1": 7.5,
                        "int16_levels_3d": 1},
            },
            "wraparound": {e: "typed-error" for e in gate.CHECKED_ENGINES},
            "roundtrip_exact": True,
            "overhead_off_x": 1.01,
            "overhead_on_x": 4.0,
        },
        "observability": {
            "overhead_x": 1.01,
            "events": {k: 2 for k in gate.OBS_EVENT_KINDS},
            "event_total": 12,
            "metric_subsystems": list(gate.OBS_SUBSYSTEMS),
            "span_subsystems": list(gate.OBS_SUBSYSTEMS),
        },
    }


def test_parse_rows_skips_header_and_malformed():
    rows = gate.parse_rows(
        "name,value,notes\nfoo.bar,3.0,a note, with commas\njunk\n"
    )
    assert rows == {"foo.bar": "3.0"}


def test_good_fixture_passes_every_gate():
    assert gate.gate_failures(_good_rows(), _good_bench()) == []


def test_summary_mentions_3d():
    s = gate.summary(_good_bench())
    assert "3d fused/per-axis" in s and s.startswith("SMOKE OK")


def test_table2_regression_fails():
    rows = _good_rows()
    rows["table2.ls.multipliers"] = "1.0"
    fails = gate.gate_failures(rows, _good_bench())
    assert any("table2.ls.multipliers" in f for f in fails)


def test_scheme_multiplies_fail():
    rows = _good_rows()
    rows["table2.scheme.97m.multipliers"] = "2.0"
    fails = gate.check_table2(rows)
    assert any("97m" in f and "multiplierless" in f for f in fails)


def test_missing_section_fails_schema_before_behaviour():
    bench = _good_bench()
    del bench["3d"]
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("missing section '3d'" in f for f in fails)


def test_row_level_schema_failure_stops_before_behavioural_gates():
    """ANY schema failure must short-circuit gate_failures: the
    behavioural gates index the payload freely and would KeyError on a
    half-broken row instead of reporting the promised failure list."""
    bench = _good_bench()
    del bench["schemes"]["cdf53"]["bit_exact"]
    fails = gate.gate_failures(_good_rows(), bench)  # must not raise
    assert any("schemes['cdf53'] missing 'bit_exact'" in f for f in fails)


def test_missing_multipliers_field_fails_schema():
    """The bench-side multiplierless check reads multipliers_per_pair;
    an emission that drops the field must fail the schema gate (not
    silently pass the behavioural one)."""
    bench = _good_bench()
    del bench["schemes"]["97m"]["multipliers_per_pair"]
    fails = gate.gate_failures(_good_rows(), bench)
    assert any(
        "schemes['97m'] missing 'multipliers_per_pair'" in f for f in fails
    )


def test_missing_3d_scheme_row_fails():
    bench = _good_bench()
    del bench["3d"]["schemes"]["haar"]
    fails = gate.check_schema(bench)
    assert any("3d.schemes" in f and "haar" in f for f in fails)


def test_3d_bit_exact_break_fails():
    bench = _good_bench()
    bench["3d"]["bit_exact"] = False
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("3d: fused volume transform diverged" in f for f in fails)


def test_3d_scheme_roundtrip_break_fails():
    bench = _good_bench()
    bench["3d"]["schemes"]["cdf22"]["bit_exact"] = False
    fails = gate.check_3d(bench)
    assert fails == ["3d scheme cdf22: volume round-trip diverged"]


def test_3d_speedup_regression_fails():
    bench = _good_bench()
    bench["3d"]["speedup_fused_vs_per_axis"] = 0.3
    fails = gate.check_3d(bench)
    assert any("regressed vs per-axis" in f for f in fails)


def test_accelerator_plan_gates():
    """On a pallas-default platform, large 2D/3D shapes must stay on the
    tiled/slab Pallas paths."""
    bench = _good_bench()
    bench["default_backend"] = "pallas"
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("2d_large" in f and "left the Pallas path" in f for f in fails)
    assert any("3d_large" in f and "left the Pallas path" in f for f in fails)
    bench["2d_large"]["plan"] = "tiled-pallas"
    bench["3d_large"]["plan"] = "slab-pallas"
    assert gate.gate_failures(_good_rows(), bench) == []


def test_interpret_speedup_floor():
    bench = _good_bench()
    bench["2d"]["speedup_fused_vs_interpret"] = 0.9
    fails = gate.check_kernels(bench)
    assert any("2d: fused compiled path no faster" in f for f in fails)


def test_codec_lossless_break_fails():
    bench = _good_bench()
    bench["codec"]["lossless"]["97m"] = False
    fails = gate.check_codec(bench)
    assert fails == ["codec scheme 97m: container roundtrip diverged"]


def test_codec_ratio_regression_fails():
    """wz-rice losing to plain zlib on the smooth checkpoint-like tensor
    is the acceptance regression the codec gate exists to catch."""
    bench = _good_bench()
    bench["codec"]["smooth"]["wz_rice_bytes"] = 70000
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("codec smooth" in f and "lost to plain zlib" in f for f in fails)


def test_codec_missing_scheme_row_fails_schema():
    bench = _good_bench()
    del bench["codec"]["lossless"]["cdf22"]
    fails = gate.check_schema(bench)
    assert any("codec.lossless" in f and "cdf22" in f for f in fails)


def test_codec_missing_ratio_key_fails_schema():
    bench = _good_bench()
    del bench["codec"]["noisy"]["zlib_bytes"]
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("codec.noisy missing key 'zlib_bytes'" in f for f in fails)


def test_summary_mentions_codec():
    assert "codec lossless" in gate.summary(_good_bench())


def test_resilience_silent_corruption_fails():
    """A bit-flip that decodes without healing is silent corruption —
    the one outcome the resilience layer exists to rule out."""
    bench = _good_bench()
    bench["resilience"]["recovery"]["bit-flip"] = "silent"
    fails = gate.check_resilience(bench)
    assert any("bit-flip" in f and "'silent'" in f for f in fails)


def test_resilience_heal_break_fails():
    bench = _good_bench()
    bench["resilience"]["single_band_recovery"] = False
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("did NOT heal" in f for f in fails)


def test_resilience_parity_ratio_bounds():
    """Parity must cost something (>0: the group really exists) but
    never approach a full duplicate (<1)."""
    for bad in (0, 1.0, 2.5, -0.1, True):
        bench = _good_bench()
        bench["resilience"]["parity_overhead_ratio"] = bad
        fails = gate.check_resilience(bench)
        assert any("parity_overhead_ratio" in f for f in fails), bad


def test_resilience_missing_fault_class_fails():
    bench = _good_bench()
    del bench["resilience"]["recovery"]["stuck-neighbor"]
    fails = gate.check_resilience(bench)
    assert any("stuck-neighbor" in f and "missing" in f for f in fails)


def test_resilience_unknown_fault_class_fails():
    """Taxonomy and gate move together: a new fault class emitted by the
    bench without a pinned expectation here must fail loudly."""
    bench = _good_bench()
    bench["resilience"]["recovery"]["cosmic-ray"] = "recovered"
    fails = gate.check_resilience(bench)
    assert any("cosmic-ray" in f and "unknown fault class" in f for f in fails)


def test_resilience_missing_section_fails_schema():
    bench = _good_bench()
    del bench["resilience"]
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("missing section 'resilience'" in f for f in fails)


def test_summary_mentions_resilience():
    s = gate.summary(_good_bench())
    assert "resilience parity=0.18" in s and "band-heal=True" in s


def test_ranges_silent_wraparound_fails():
    """A checked engine that lets a wrapping input through silently is
    the exact corruption mode the certificates exist to rule out."""
    bench = _good_bench()
    bench["ranges"]["wraparound"]["fused-3d"] = "silent"
    fails = gate.check_ranges(bench)
    assert any("fused-3d" in f and "silently" in f for f in fails)


def test_ranges_missing_engine_fails():
    bench = _good_bench()
    del bench["ranges"]["wraparound"]["sharded-2d"]
    fails = gate.check_ranges(bench)
    assert any("sharded-2d" in f and "missing" in f for f in fails)


def test_ranges_unknown_engine_fails():
    bench = _good_bench()
    bench["ranges"]["wraparound"]["warp-engine"] = "typed-error"
    fails = gate.check_ranges(bench)
    assert any("warp-engine" in f and "unknown engine" in f for f in fails)


def test_ranges_certificate_pin():
    """A drifted cdf53 certificate means the tracer's semantics moved."""
    bench = _good_bench()
    bench["ranges"]["certificates"]["cdf53"]["safe_abs_1d_l1"] += 1
    fails = gate.check_ranges(bench)
    assert any("pinned" in f for f in fails)


def test_ranges_monotonicity_and_missing_scheme():
    bench = _good_bench()
    bench["ranges"]["certificates"]["haar"]["safe_abs_2d_l2"] = 0
    fails = gate.check_ranges(bench)
    assert any("haar" in f and "positive-monotone" in f for f in fails)
    bench2 = _good_bench()
    del bench2["ranges"]["certificates"]["97m"]
    fails2 = gate.check_ranges(bench2)
    assert any("97m" in f for f in fails2)


def test_ranges_checked_off_must_be_free():
    bench = _good_bench()
    bench["ranges"]["overhead_off_x"] = 5.2
    fails = gate.check_ranges(bench)
    assert any("not free" in f for f in fails)


def test_ranges_roundtrip_break_fails():
    bench = _good_bench()
    bench["ranges"]["roundtrip_exact"] = False
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("round-trip bit-exactly under checked" in f for f in fails)


def test_ranges_missing_section_fails_schema():
    bench = _good_bench()
    del bench["ranges"]
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("missing section 'ranges'" in f for f in fails)


def test_summary_mentions_ranges():
    s = gate.summary(_good_bench())
    assert "ranges checked=6 engines typed" in s


def test_serve_cache_miss_after_warmup_fails():
    """A hit rate below 1.0 means something recompiled under the warmed
    mixed-bucket workload — the exact regression the executable cache
    exists to rule out."""
    bench = _good_bench()
    bench["serve"]["cache_hit_rate"] = 0.75
    fails = gate.check_serve(bench)
    assert any("hit rate 0.75" in f and "recompiled" in f for f in fails)


def test_serve_recompile_per_request_fails():
    bench = _good_bench()
    bench["serve"]["compiles"] = 7
    fails = gate.check_serve(bench)
    assert any("7 compiles for 2 buckets" in f for f in fails)


def test_serve_batch_encode_speedup_floor():
    bench = _good_bench()
    bench["serve"]["batch_encode_speedup"] = 1.2
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("below the 1.5x floor" in f for f in fails)


def test_serve_thumbnail_fraction_bounds():
    """The thumbnail tier must read a STRICT byte subset: a fraction of
    1.0 means progressive decode degenerated into a full read, 0 or
    negative means the accounting broke."""
    for bad in (0, 1.0, 1.7, -0.2, True):
        bench = _good_bench()
        bench["serve"]["thumbnail_bytes_fraction"] = bad
        fails = gate.check_serve(bench)
        assert any("thumbnail tier" in f for f in fails), bad


def test_serve_nonpositive_throughput_fails():
    bench = _good_bench()
    bench["serve"]["requests_per_s"] = 0
    fails = gate.check_serve(bench)
    assert any("non-positive throughput" in f for f in fails)


def test_serve_missing_section_fails_schema():
    bench = _good_bench()
    del bench["serve"]
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("missing section 'serve'" in f for f in fails)


def test_summary_mentions_serve():
    s = gate.summary(_good_bench())
    assert "serve 100.0 req/s" in s and "hit-rate=1.0" in s


def test_obs_overhead_over_budget_fails():
    """Instrumentation costing more than the gate budget on the serve
    workload means it is no longer cheap enough to leave on."""
    bench = _good_bench()
    bench["observability"]["overhead_x"] = 1.25
    fails = gate.check_obs(bench)
    assert any("too expensive to leave on" in f for f in fails)


def test_obs_subsystem_going_dark_fails():
    bench = _good_bench()
    bench["observability"]["metric_subsystems"].remove("codec")
    fails = gate.gate_failures(_good_rows(), bench)
    assert any(
        "metric_subsystems" in f and "codec" in f for f in fails
    )


def test_obs_span_coverage_checked_separately():
    bench = _good_bench()
    bench["observability"]["span_subsystems"] = ["serve"]
    fails = gate.check_obs(bench)
    assert any("span_subsystems" in f for f in fails)
    assert not any("metric_subsystems" in f for f in fails)


def test_obs_silent_event_site_fails():
    """A chaos run that produces zero events of a kind means that event
    site stopped emitting — the instrumentation analogue of silent
    corruption."""
    bench = _good_bench()
    bench["observability"]["events"]["RetryEvent"] = 0
    fails = gate.check_obs(bench)
    assert any("no RetryEvent" in f for f in fails)
    bench["observability"]["events"].pop("HealEvent")
    fails = gate.check_obs(bench)
    assert any("no HealEvent" in f for f in fails)


def test_obs_event_total_below_ring_count_fails():
    bench = _good_bench()
    bench["observability"]["event_total"] = 3
    fails = gate.check_obs(bench)
    assert any("unbounded total regressed" in f for f in fails)


def test_obs_missing_section_fails_schema():
    bench = _good_bench()
    del bench["observability"]
    fails = gate.gate_failures(_good_rows(), bench)
    assert any("missing section 'observability'" in f for f in fails)


def test_summary_mentions_obs():
    s = gate.summary(_good_bench())
    assert "obs overhead=1.01x" in s and "subsystems=5" in s


def test_main_exit_codes(tmp_path):
    csv = tmp_path / "rows.csv"
    csv.write_text(
        "name,value,notes\n"
        + "\n".join(f"{k},{v},x" for k, v in _good_rows().items())
        + "\n"
    )
    bench_path = tmp_path / "bench.json"
    bench_path.write_text(json.dumps(_good_bench()))
    assert gate.main(["--csv", str(csv), "--bench", str(bench_path)]) == 0
    broken = _good_bench()
    broken["bit_exact"] = False
    bench_path.write_text(json.dumps(broken))
    assert gate.main(["--csv", str(csv), "--bench", str(bench_path)]) == 1


def test_fixture_stays_schema_complete():
    """The passing fixture must keep covering every required section/key
    (otherwise the failing-fixture tests could rot into vacuity)."""
    bench = _good_bench()
    assert gate.check_schema(bench) == []
    mutated = copy.deepcopy(bench)
    mutated["3d"].pop("speedup_fused_vs_per_axis")
    assert gate.check_schema(mutated) != []
