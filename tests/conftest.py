"""Tier-1 test configuration.

Registers the deterministic ``hypothesis`` fallback shim when the real
package is unavailable (kernel CI images bake in only the jax/pallas
toolchain), so test collection succeeds everywhere.  The real hypothesis
always wins when installed; pin it via requirements-dev.txt locally.
"""
import importlib.util
import pathlib
import sys

try:  # real hypothesis preferred
    import hypothesis  # noqa: F401
except ImportError:
    _path = pathlib.Path(__file__).with_name("hypothesis_shim.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies
