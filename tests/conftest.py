"""Tier-1 test configuration.

Registers the deterministic ``hypothesis`` fallback shim when the real
package is unavailable (kernel CI images bake in only the jax/pallas
toolchain), so test collection succeeds everywhere.  The real hypothesis
always wins when installed; pin it via requirements-dev.txt locally.

Warning policy: every RuntimeWarning is an ERROR except
``BackendDegradeWarning`` (the dedicated category for backend-degrade
notices, ``kernels/backend.py``), which is expected on CPU runs — an
explicit ``pallas`` request legitimately degrades to the emulator
off-accelerator.  The seed leaked those notices into the pytest warnings
summary; with the dedicated category filtered and everything else
escalated, a degrade-warning leak (or any new stray RuntimeWarning)
fails the tier-1 suite — and therefore the CI smoke gate — outright.
Filters are ini-ordered: the later (more specific) line wins.
"""
import importlib.util
import pathlib
import sys

try:  # real hypothesis preferred
    import hypothesis  # noqa: F401
except ImportError:
    _path = pathlib.Path(__file__).with_name("hypothesis_shim.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies


def pytest_configure(config):
    config.addinivalue_line("filterwarnings", "error::RuntimeWarning")
    config.addinivalue_line(
        "filterwarnings",
        "ignore::repro.kernels.backend.BackendDegradeWarning",
    )
    # degraded-but-correct resilience notices (retry succeeded, restore
    # self-healed, encode degraded) are expected under chaos injection;
    # tests assert them explicitly with pytest.warns where they matter
    config.addinivalue_line(
        "filterwarnings",
        "ignore::repro.resilience.errors.ResilienceWarning",
    )
    # CI lanes (.github/workflows/ci.yml): the PR lane runs -m "not slow"
    # for fast feedback; the main-branch lane runs the full suite.
    config.addinivalue_line(
        "markers",
        "slow: long-running sweep (2048x2048 images, CPU-mesh subprocess "
        "sweeps); excluded from the CI pull-request lane via -m 'not slow'",
    )
    config.addinivalue_line(
        "markers",
        "sharded: spawns subprocesses with a forced multi-device CPU mesh "
        "(XLA_FLAGS=--xla_force_host_platform_device_count)",
    )
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection suite (tests/test_resilience.py) — every "
        "injected fault must recover bit-exactly, degrade with a typed "
        "warning, or fail with a typed error; CI runs it as its own lane "
        "with a fixed REPRO_CHAOS_SEED",
    )
