"""Wavelet gradient/tensor compression tests (core + train integration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


def test_quantize_dequantize_bounds():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)), jnp.float32)
    scale = C.tensor_scale(g)
    q = C.quantize(g, scale)
    back = C.dequantize(q, scale)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-7


def test_lowband_roundtrip_shapes():
    rng = np.random.default_rng(1)
    for shape in [(100,), (33, 7), (4, 5, 6)]:
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        g_hat, resid = C.lossy_roundtrip(g, levels=2)
        assert g_hat.shape == g.shape
        assert resid.shape == g.shape
        # reconstruction + residual == original (exact bookkeeping)
        np.testing.assert_allclose(
            np.asarray(g_hat + resid), np.asarray(g), rtol=1e-5, atol=1e-5
        )


def test_lowband_preserves_smooth_signals():
    """Low-band channel is near-exact for smooth (low-frequency) tensors."""
    t = jnp.linspace(0, 3.0, 4096)
    g = jnp.sin(t) * 2.0 + 0.5 * jnp.cos(3 * t)
    g_hat, _ = C.lossy_roundtrip(g, levels=2)
    rel = float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
    assert rel < 0.01


def test_band_quantized_roundtrip_accuracy():
    """Production codec: <5% single-step distortion on white noise."""
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)
    g_hat, resid = C.band_quantized_roundtrip(g, levels=2)
    rel = float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
    assert rel < 0.05
    np.testing.assert_allclose(
        np.asarray(g_hat + resid), np.asarray(g), rtol=1e-4, atol=1e-4
    )


def test_error_feedback_drains_band_codec():
    """With the band codec, EF must make cumulative error << sum of per-step."""
    rng = np.random.default_rng(3)
    g_true = jnp.asarray(rng.standard_normal((4096,)), jnp.float32)
    rt = jax.jit(lambda g: C.band_quantized_roundtrip(g, levels=2))
    err = jnp.zeros_like(g_true)
    applied = jnp.zeros_like(g_true)
    for t in range(15):
        g_hat, err = rt(g_true + err)
        applied = applied + g_hat
    rel = float(jnp.linalg.norm(applied - 15 * g_true) / jnp.linalg.norm(15 * g_true))
    single = float(jnp.linalg.norm(rt(g_true)[0] - g_true) / jnp.linalg.norm(g_true))
    assert rel < single / 2  # EF must drain, not accumulate


def test_band_bytes_accounting():
    n = 10000
    b = C.band_bytes(n, levels=2)
    # approx n/4 int16 + details 3n/4 int8 (+ padding + scalars)
    assert b < n * 4 / 3.0  # at least 3x smaller than fp32
    assert b > n  # but not magically below 1 byte/coeff


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=64, max_value=5000),
    levels=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_property_band_codec_bookkeeping(n, levels, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((n,)) * 10.0, jnp.float32)
    g_hat, resid = C.band_quantized_roundtrip(g, levels=levels)
    assert bool(jnp.isfinite(g_hat).all())
    np.testing.assert_allclose(
        np.asarray(g_hat + resid), np.asarray(g), rtol=1e-4, atol=1e-4
    )
    rel = float(jnp.linalg.norm(g_hat - g) / (jnp.linalg.norm(g) + 1e-9))
    assert rel < 0.08


# ---------------------------------------------------------------------------
# 2D (spatial) band codec — routed through the tiled/fused 2D engine.
# ---------------------------------------------------------------------------


def test_band_quantized_roundtrip_2d_accuracy():
    rng = np.random.default_rng(17)
    g = jnp.asarray(rng.standard_normal((3, 48, 65)), jnp.float32)
    g_hat, resid = C.band_quantized_roundtrip_2d(g, levels=2)
    np.testing.assert_allclose(
        np.asarray(g_hat + resid), np.asarray(g), rtol=1e-4, atol=1e-4
    )
    rel = float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
    assert rel < 0.08


def test_2d_codec_beats_1d_on_smooth_matrices():
    """Smoothness along BOTH axes: the 2D pyramid's detail bands carry
    less energy than the flattened 1D transform's, so the int8 bands
    quantize with less error."""
    rng = np.random.default_rng(23)
    yy, xx = np.meshgrid(np.linspace(0, 3, 96), np.linspace(0, 3, 64), indexing="ij")
    g = jnp.asarray(
        np.sin(yy) * np.cos(xx) + 0.01 * rng.standard_normal((96, 64)),
        jnp.float32,
    )
    hat_2d, _ = C.band_quantized_roundtrip_2d(g, levels=2)
    hat_1d, _ = C.band_quantized_roundtrip(g, levels=2)
    err_2d = float(jnp.linalg.norm(hat_2d - g))
    err_1d = float(jnp.linalg.norm(hat_1d - g))
    assert err_2d <= err_1d


def test_band_bytes_2d_accounting():
    b = C.band_bytes_2d(64, 96, levels=2)
    n = 64 * 96
    assert b < n * 4 / 3.0
    assert b > n // 2


def test_pack2d_unpack2d_roundtrip():
    from repro import kernels as K
    from repro.core import lifting

    rng = np.random.default_rng(29)
    x = jnp.asarray(rng.integers(-500, 500, (2, 33, 47)), jnp.int32)
    pyr = lifting.dwt53_fwd_2d_multi(x, levels=3)
    pyr2 = K.unpack2d(K.pack2d(pyr), 33, 47, 3)
    np.testing.assert_array_equal(
        np.asarray(lifting.dwt53_inv_2d_multi(pyr2)), np.asarray(x)
    )


def test_band_quantized_roundtrip_nd_accuracy():
    """The 3D band codec reconstructs smooth volumes within quantization
    error, for both the default and an alternate scheme."""
    rng = np.random.default_rng(9)
    t = np.linspace(0, 1, 6)[:, None, None]
    yy = np.linspace(0, 1, 16)[None, :, None]
    xx = np.linspace(0, 1, 24)[None, None, :]
    g = jnp.asarray(
        (np.sin(4 * t + 2 * yy) * np.cos(3 * xx)
         + 0.01 * rng.normal(size=(6, 16, 24))).astype(np.float32)
    )
    for scheme in ("cdf53", "97m"):
        g_hat, resid = C.band_quantized_roundtrip_nd(g, levels=2, scheme=scheme)
        rel = float(jnp.linalg.norm(resid) / jnp.linalg.norm(g))
        assert rel < 0.05, (scheme, rel)


def test_band_bytes_nd_accounting():
    shape = (6, 16, 24)
    n = 6 * 16 * 24
    got = C.band_bytes_nd(shape, 2)
    assert got < n * 4  # beats fp32
    # exact accounting against the band geometry
    from repro.core import lifting

    a_shape, det_shapes = lifting.band_shapes_nd(shape, 2)
    want = 2 * int(np.prod(a_shape)) + sum(
        int(np.prod(b)) for lvl in det_shapes for b in lvl
    ) + 8
    assert got == want


def test_nd_codec_batched_lead_dims():
    rng = np.random.default_rng(10)
    g = jnp.asarray(rng.normal(size=(2, 4, 8, 8)).astype(np.float32))
    g_hat, resid = C.band_quantized_roundtrip_nd(g, levels=1)
    assert g_hat.shape == g.shape
    assert float(jnp.linalg.norm(resid) / jnp.linalg.norm(g)) < 0.1
