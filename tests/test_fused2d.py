"""Fused 2D row-column kernel tests: bit-exact vs the 4-transpose oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused2d, ref

RNG = np.random.default_rng(23)

SHAPES = [(2, 2), (3, 3), (8, 8), (16, 17), (17, 16), (33, 33), (64, 64), (65, 128)]
MODES = ["paper", "jpeg2000"]
BACKENDS = [None, "xla", "interpret"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("hw", SHAPES)
def test_fwd2d_matches_ref(hw, mode, backend):
    x = jnp.asarray(RNG.integers(-1000, 1000, size=hw), jnp.int32)
    got = fused2d.dwt53_fwd_2d(x, mode=mode, backend=backend)
    want = ref.dwt53_fwd_2d(x, mode=mode)
    for name in ("ll", "lh", "hl", "hh"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("hw", SHAPES)
def test_inv2d_roundtrip(hw, mode, backend):
    x = jnp.asarray(RNG.integers(-1000, 1000, size=hw), jnp.int32)
    bands = fused2d.dwt53_fwd_2d(x, mode=mode, backend=backend)
    xr = fused2d.dwt53_inv_2d(bands, mode=mode, backend=backend)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_fwd2d_batched_leading_dims(backend):
    x = jnp.asarray(RNG.integers(0, 255, size=(2, 3, 32, 48)), jnp.int32)
    got = fused2d.dwt53_fwd_2d(x, backend=backend)
    want = ref.dwt53_fwd_2d(x)
    assert got.ll.shape == (2, 3, 16, 24)
    for name in ("ll", "lh", "hl", "hh"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        )
    xr = fused2d.dwt53_inv_2d(got, backend=backend)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


def test_fwd2d_int8_promotes():
    x = jnp.asarray(RNG.integers(-128, 127, size=(16, 16)), jnp.int8)
    got = fused2d.dwt53_fwd_2d(x, backend="interpret")
    assert got.ll.dtype == jnp.int32
    want = ref.dwt53_fwd_2d(x.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got.ll), np.asarray(want.ll))


def test_fwd2d_large_image_takes_tiled_pallas_path():
    """Images past the whole-image VMEM budget stay on the Pallas engine
    (the tiled halo-window kernels) — there is no XLA cliff anymore."""
    from repro.kernels import backend as B

    h = w = int(np.sqrt(B.fused2d_budget_elems())) + 8  # just past budget
    assert fused2d._use_tiled(h, w)  # dispatch decision, pre-compute
    x = jnp.asarray(RNG.integers(-100, 100, size=(h, w)), jnp.int32)
    got = fused2d.dwt53_fwd_2d(x, backend="interpret")
    want = ref.dwt53_fwd_2d(x)
    np.testing.assert_array_equal(np.asarray(got.ll), np.asarray(want.ll))
    np.testing.assert_array_equal(np.asarray(got.hh), np.asarray(want.hh))
    xr = fused2d.dwt53_inv_2d(got, backend="interpret")
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


def test_fwd2d_rejects_degenerate():
    with pytest.raises(ValueError):
        fused2d.dwt53_fwd_2d(jnp.zeros((1, 8), jnp.int32))
    with pytest.raises(ValueError):
        fused2d.dwt53_fwd_2d(jnp.zeros((8,), jnp.int32))


# ---------------------------------------------------------------------------
# Fused multi-level 2D pyramid (one compiled dispatch).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("hw,levels", [((32, 48), 2), ((33, 47), 3), ((16, 16), 1)])
def test_fwd2d_multi_matches_ref(hw, levels, mode, backend):
    from repro.core import lifting

    x = jnp.asarray(RNG.integers(-1000, 1000, size=(2,) + hw), jnp.int32)
    got = fused2d.dwt53_fwd_2d_multi(x, levels=levels, mode=mode, backend=backend)
    want = lifting.dwt53_fwd_2d_multi(x, levels=levels, mode=mode)
    np.testing.assert_array_equal(np.asarray(got.ll), np.asarray(want.ll))
    for got_lvl, want_lvl in zip(got.details, want.details):
        for g, w in zip(got_lvl, want_lvl):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    xr = fused2d.dwt53_inv_2d_multi(got, mode=mode, backend=backend)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


def test_fwd2d_multi_is_one_dispatch():
    """All pyramid levels trace into a single compiled computation."""
    fused2d._fwd2d_multi_kernel._clear_cache()
    x = jnp.asarray(RNG.integers(0, 255, size=(1, 64, 64)), jnp.int32)
    fused2d.dwt53_fwd_2d_multi(x, levels=3, backend="interpret")
    fused2d.dwt53_fwd_2d_multi(x, levels=3, backend="interpret")
    assert fused2d._fwd2d_multi_kernel._cache_size() == 1


def test_fwd2d_multi_rejects_too_deep():
    with pytest.raises(ValueError, match="too small"):
        fused2d.dwt53_fwd_2d_multi(jnp.zeros((4, 4), jnp.int32), levels=4)


def test_inv2d_multi_rejects_malformed():
    from repro.core import lifting

    x = jnp.asarray(RNG.integers(0, 255, size=(24, 24)), jnp.int32)
    pyr = lifting.dwt53_fwd_2d_multi(x, levels=2)
    bad = lifting.Pyramid2D(
        ll=jnp.pad(pyr.ll, ((0, 1), (0, 0))),
        details=pyr.details,
    )
    with pytest.raises(ValueError, match="band shape mismatch"):
        fused2d.dwt53_inv_2d_multi(bad)
