"""Fused 2D row-column kernel tests: bit-exact vs the 4-transpose oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused2d, ref

RNG = np.random.default_rng(23)

SHAPES = [(2, 2), (3, 3), (8, 8), (16, 17), (17, 16), (33, 33), (64, 64), (65, 128)]
MODES = ["paper", "jpeg2000"]
BACKENDS = [None, "xla", "interpret"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("hw", SHAPES)
def test_fwd2d_matches_ref(hw, mode, backend):
    x = jnp.asarray(RNG.integers(-1000, 1000, size=hw), jnp.int32)
    got = fused2d.dwt53_fwd_2d(x, mode=mode, backend=backend)
    want = ref.dwt53_fwd_2d(x, mode=mode)
    for name in ("ll", "lh", "hl", "hh"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("hw", SHAPES)
def test_inv2d_roundtrip(hw, mode, backend):
    x = jnp.asarray(RNG.integers(-1000, 1000, size=hw), jnp.int32)
    bands = fused2d.dwt53_fwd_2d(x, mode=mode, backend=backend)
    xr = fused2d.dwt53_inv_2d(bands, mode=mode, backend=backend)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_fwd2d_batched_leading_dims(backend):
    x = jnp.asarray(RNG.integers(0, 255, size=(2, 3, 32, 48)), jnp.int32)
    got = fused2d.dwt53_fwd_2d(x, backend=backend)
    want = ref.dwt53_fwd_2d(x)
    assert got.ll.shape == (2, 3, 16, 24)
    for name in ("ll", "lh", "hl", "hh"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        )
    xr = fused2d.dwt53_inv_2d(got, backend=backend)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))


def test_fwd2d_int8_promotes():
    x = jnp.asarray(RNG.integers(-128, 127, size=(16, 16)), jnp.int8)
    got = fused2d.dwt53_fwd_2d(x, backend="interpret")
    assert got.ll.dtype == jnp.int16
    want = ref.dwt53_fwd_2d(x.astype(jnp.int16))
    np.testing.assert_array_equal(np.asarray(got.ll), np.asarray(want.ll))


def test_fwd2d_large_image_falls_back():
    """Images past the VMEM budget take the XLA path and stay bit-exact."""
    from repro.kernels import backend as B

    h = w = int(np.sqrt(B.FUSED2D_MAX_ELEMS)) + 8  # just past the budget
    x = jnp.asarray(RNG.integers(-100, 100, size=(h, w)), jnp.int32)
    got = fused2d.dwt53_fwd_2d(x, backend="interpret")
    want = ref.dwt53_fwd_2d(x)
    np.testing.assert_array_equal(np.asarray(got.ll), np.asarray(want.ll))
    np.testing.assert_array_equal(np.asarray(got.hh), np.asarray(want.hh))


def test_fwd2d_rejects_degenerate():
    with pytest.raises(ValueError):
        fused2d.dwt53_fwd_2d(jnp.zeros((1, 8), jnp.int32))
    with pytest.raises(ValueError):
        fused2d.dwt53_fwd_2d(jnp.zeros((8,), jnp.int32))
