"""The observability layer end to end: a bucketed serve run with the
``repro.obs`` instrumentation live, a fault armed so the event taxonomy
lights up, the metrics snapshot printed, and a Chrome-trace JSON
artifact written for Perfetto.

    PYTHONPATH=src python examples/observe_serve.py

Walks the whole PR-10 surface:

  1. serve a mixed-bucket request stream; the scheduler, executor,
     engine, and codec publish counters / gauges / histograms into the
     process-wide registry as a side effect of normal operation
  2. arm one transient transform fault: the retry ladder emits
     RetryEvent -> HealEvent (and the RetryWarning still fires, with
     its category intact)
  3. print ``obs.snapshot()`` — every metric series, event counts, and
     per-subsystem span counts in one dict — plus the p50/p95/p99 of
     the batch-latency histogram and the Prometheus text exposition
  4. write the recorded spans as Chrome-trace JSON; open the file at
     https://ui.perfetto.dev to see the serve steps, codec encodes,
     and retry timing on one timeline
"""
import json
import warnings

import numpy as np

from repro import obs
from repro.resilience import inject
from repro.serve import TransformRequest, WaveletServeEngine

TRACE_PATH = "observe_serve_trace.json"


def main():
    rng = np.random.default_rng(7)
    obs.reset()  # a clean ledger so the printout is this run only

    engine = WaveletServeEngine(
        buckets=((16, 16), (32, 32)),
        batch_slots=4,
        levels=2,
        encode_response=True,
    )
    engine.warmup()

    shapes = [(16, 16), (13, 11), (32, 24), (32, 32), (28, 30), (16, 12),
              (32, 32), (9, 9)]
    for uid, (h, w) in enumerate(shapes):
        img = rng.integers(-2048, 2048, (h, w)).astype(np.int32)
        engine.submit(TransformRequest(uid=uid, image=img))

    # one transient fault on the first batch: the retry ladder recovers,
    # and the obs layer records the whole episode
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        with inject.armed("serve.transform", times=1):
            while engine.scheduler.pending():
                engine.step()
    print(f"served {len(shapes)} requests; {len(caught)} warning(s) "
          f"fired ({', '.join(type(w.message).__name__ for w in caught)})")

    snap = obs.snapshot()
    print("\n-- obs.snapshot() --")
    print(json.dumps(snap, indent=2, default=str))

    lat = obs.histogram("serve.batch_latency_ms", bucket="32x32").summary()
    print(f"\n32x32 batch latency: n={lat['count']} p50={lat['p50']:.3g}ms "
          f"p95={lat['p95']:.3g}ms p99={lat['p99']:.3g}ms")

    retries = obs.events.query(kind=obs.RetryEvent)
    heals = obs.events.query(kind=obs.HealEvent)
    print(f"retry episode: {len(retries)} retry -> {len(heals)} heal "
          f"({heals[0].mechanism if heals else 'none'})")

    print("\n-- Prometheus exposition (first 15 lines) --")
    print("\n".join(obs.render_prometheus().splitlines()[:15]))

    path = obs.write_chrome_trace(TRACE_PATH)
    n_spans = len(obs.export_chrome_trace()["traceEvents"])
    print(f"\nwrote {n_spans} spans to {path} — load it at "
          "https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
