"""End-to-end training driver: train a ~100M-param LM on the synthetic
pipeline with checkpointing + watchdog.

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 50   # CI

The 100m preset is the deliverable configuration; `tiny` runs the same
code path in seconds on CPU.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.launch.train import train
from repro.train import optim

PRESETS = {
    # ~103M params: 12L x 768d, vocab 16384, swiglu — stablelm family
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                 d_ff=2048, vocab_size=16384, seq=256, batch=8),
    # ~10M: CI-speed
    "10m": dict(n_layers=6, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
                d_ff=1024, vocab_size=8192, seq=128, batch=8),
    "tiny": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                 d_ff=256, vocab_size=512, seq=64, batch=4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    p = dict(PRESETS[args.preset])
    seq, batch = p.pop("seq"), p.pop("batch")
    base = get_config("stablelm-1.6b")
    cfg = dataclasses.replace(
        base, param_dtype="float32", compute_dtype="float32", attn_chunk=64, **p
    )
    n_params = cfg.param_count()
    print(f"preset={args.preset}: {n_params/1e6:.1f}M params, "
          f"seq={seq} batch={batch} steps={args.steps}")
    out = train(
        cfg,
        steps=args.steps,
        global_batch=batch,
        seq_len=seq,
        ckpt_dir=args.ckpt_dir,
        opt_cfg=optim.AdamWConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 1),
                                  total_steps=args.steps),
        log_every=max(args.steps // 20, 1),
    )
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"in {out['wall_s']:.0f}s ({out['steps']} steps)")
    assert out["final_loss"] < out["first_loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
