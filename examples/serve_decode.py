"""Production serve tier end to end: bucketed transform serving with a
compiled-executable cache, batch-level WZRC encode, and progressive
thumbnail -> refinement -> full decode from ONE stored bitstream.

    PYTHONPATH=src python examples/serve_decode.py

Walks the whole PR-8 surface:

  1. submit mixed-shape integer images; the scheduler routes each to its
     nearest bucket (zero-pad admission) and forms micro-batches
  2. the executor runs each batch through ONE cached compiled executable
     per bucket — after warmup the cache never misses
  3. each micro-batch is encoded into a single shared WZRC container
     (lead dim = batch); per-request responses carry a row index
  4. the progressive route serves the LL thumbnail from a byte-range
     read, then refines tier by tier, then reconstructs the original
     samples bit-exactly — all from the same stored blob

Also runs the original LM continuous-batching demo (repro.serve keeps
both engines).
"""
import time

import numpy as np

import jax

from repro import codec
from repro.codec import progressive
from repro.serve import ProgressiveServeRoute, TransformRequest, WaveletServeEngine


def wavelet_demo():
    rng = np.random.default_rng(7)
    engine = WaveletServeEngine(
        buckets=((16, 16), (32, 32), (64, 64)),
        batch_slots=4,
        levels=2,
        encode_response=True,
    )
    compiled = engine.warmup()
    print(f"warmup compiled {compiled} executables "
          f"(one per bucket: {engine.scheduler.buckets})")

    # mixed shapes: exact fits and zero-padded admissions
    shapes = [(16, 16), (13, 11), (32, 24), (64, 48), (28, 30), (16, 12)]
    requests = []
    for uid, (h, w) in enumerate(shapes):
        img = rng.integers(-2048, 2048, (h, w)).astype(np.int32)
        requests.append(TransformRequest(uid=uid, image=img))

    ex = engine.executor
    warm_misses = ex.misses
    t0 = time.perf_counter()
    done = engine.run(requests)
    dt = time.perf_counter() - t0
    new_misses = ex.misses - warm_misses
    print(f"served {len(done)} requests in {dt * 1e3:.1f} ms — "
          f"{ex.hits} cache hits, {new_misses} recompiles after warmup")
    assert new_misses == 0

    shared = len({id(r.encoded) for r in done if r.batch_index is not None})
    print(f"batch-level encode: {len(done)} responses share "
          f"{shared} container(s)")

    # progressive serving: thumbnail first, refine on demand
    route = ProgressiveServeRoute()
    for r in done:
        route.store(r)
    uid = 3  # the (64, 48) request
    blob = done[uid].encoded
    reader = progressive.CountingReader(blob)
    codec.decode_lowband(reader)  # byte-range read, counted by the reader
    print(f"req {uid}: thumbnail {tuple(route.thumbnail(uid).shape)} from "
          f"{reader.bytes_read}/{len(blob)} bytes "
          f"({reader.bytes_read / len(blob):.1%} of the container)")
    for level, shape in route.tiers(uid).items():
        print(f"  tier {level}: {shape}")
    full = route.full(uid)
    exact = bool(np.array_equal(np.asarray(full), requests[uid].image))
    print(f"  full tier bit-exact vs submitted image: {exact}")
    assert exact


def lm_demo():
    from repro.configs import get_config, reduced
    from repro.models import layers as L
    from repro.models import transformer as T
    from repro.serve.serve_step import Request, ServeEngine

    cfg = reduced(get_config("granite-3-8b"))
    params = L.init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=4, prefill_len=16)

    rng = np.random.default_rng(1)
    requests = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(2, 12)).astype(np.int32),
                max_new=int(rng.integers(4, 12)))
        for i in range(10)
    ]
    t0 = time.perf_counter()
    done = engine.run(requests)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} LM requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU, reduced config)")


def main():
    print("== wavelet transform serving (bucketed + progressive) ==")
    wavelet_demo()
    print("\n== LM continuous batching ==")
    lm_demo()


if __name__ == "__main__":
    main()
