"""Batched serving example: continuous-batching engine over a small model.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve.serve_step import Request, ServeEngine


def main():
    cfg = reduced(get_config("granite-3-8b"))
    params = L.init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=4, prefill_len=16)

    rng = np.random.default_rng(1)
    requests = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(2, 12)).astype(np.int32),
                max_new=int(rng.integers(4, 12)))
        for i in range(10)
    ]
    t0 = time.perf_counter()
    done = engine.run(requests)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU, reduced config)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt {len(r.prompt)} toks -> {r.out_tokens}")


if __name__ == "__main__":
    main()
