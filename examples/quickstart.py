"""Quickstart: the paper's integer (5,3) lifting DWT in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import lifting as L
from repro.core.opcount import arithmetic_summary, lifting_pair, example_int_args
from repro.core.pe import AnalysisModule, ReconstructionModule
from repro.kernels import ops


def main():
    # --- the paper's Fig.5 experiment: 64 samples, lossless round trip ----
    rng = np.random.default_rng(2010)
    x = jnp.asarray(
        np.clip(np.round(rng.normal(128, 40, size=64)), 0, 255).astype(np.int32)[None]
    )
    s, d = L.dwt53_fwd_1d(x)  # eq. (5) + eq. (7)
    x_rec = L.dwt53_inv_1d(s, d)  # eqs. (8)-(10)
    print("signal[:8]       ", np.asarray(x)[0, :8])
    print("approx s[:4]     ", np.asarray(s)[0, :4])
    print("details d[:4]    ", np.asarray(d)[0, :4])
    print("lossless?        ", bool((x_rec == x).all()))

    # --- multi-level + non-power-of-two length ----------------------------
    y = jnp.asarray(rng.integers(0, 255, size=(1, 321)), jnp.int32)
    pyr = L.dwt53_fwd(y, levels=4)
    print("321 samples, 4 levels, lossless?", bool((L.dwt53_inv(pyr) == y).all()))

    # --- the multiplierless claim (Table 2) -------------------------------
    print("ops per output pair:", arithmetic_summary(lifting_pair, *example_int_args(4)))

    # --- the hardware PE model (Fig. 2-4) ---------------------------------
    am = AnalysisModule()
    s_pe, d_pe = am.process(np.asarray(x)[0])
    rm = ReconstructionModule()
    ok = rm.process(s_pe, d_pe) == [int(v) for v in np.asarray(x)[0]]
    print("PE model bit-exact?", ok, "| ledger:", am.pe.ledger.as_dict())

    # --- the kernel engine (compiled by default: Pallas on TPU/GPU, XLA
    # reference on CPU; backend="interpret" forces the Pallas emulator) ----
    big = jnp.asarray(rng.integers(0, 255, size=(8, 4096)), jnp.int32)
    s_k, d_k = ops.dwt53_fwd_1d(big)
    print("kernel engine lossless?", bool((ops.dwt53_inv_1d(s_k, d_k) == big).all()))
    s_i, d_i = ops.dwt53_fwd_1d(big, backend="interpret")
    print("interpret == compiled?", bool((s_i == s_k).all() and (d_i == d_k).all()))

    # --- scheme selection: the (5,3) is one entry in a lifting-scheme
    # registry; every transform takes scheme="haar" / "cdf22" / "97m" /
    # anything you register (core/schemes.py §9) — same multiplierless
    # shift-add contract, same bit-exact invertibility, derived halos ----
    from repro.core import schemes as SCH

    for name in SCH.available_schemes():
        sch = SCH.get_scheme(name)
        s_n, d_n = ops.dwt_fwd_1d(big, scheme=name)
        ok = bool((ops.dwt_inv_1d(s_n, d_n, scheme=name) == big).all())
        print(
            f"scheme {name:6s} halo={sch.halo} "
            f"ops/pair={sch.pair_op_counts()} lossless? {ok}"
        )


if __name__ == "__main__":
    main()
