"""Multi-pod training with wavelet-codec gradient sync (the paper's
transform in the distributed-optimization path).

Runs on 8 emulated host devices as a (pod=2, data=2, model=2) mesh and
compares the compressed-sync step against the full-fidelity baseline.

    PYTHONPATH=src python examples/multipod_train.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticLM  # noqa: E402
from repro.launch.train import init_train_state  # noqa: E402
from repro.train import optim  # noqa: E402
from repro.train.grad_compress import WaveletSyncConfig, pod_collective_bytes  # noqa: E402
from repro.train.train_step import (  # noqa: E402
    init_podded_error_feedback,
    make_train_step,
    make_wavelet_train_step,
    podded,
    podded_opt,
)


def main():
    cfg = reduced(get_config("stablelm-1.6b"))
    mesh = jax.make_mesh(
        (2, 2, 2), ("pod", "data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 3,
    )
    state = init_train_state(cfg, 0)
    opt_cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    sync = WaveletSyncConfig(levels=2, codec="bands", n_pods=2, min_size=256)
    raw, comp = pod_collective_bytes(state["params"], sync)
    print(f"inter-pod gradient sync: {raw} -> {comp} wire bytes "
          f"({raw / comp:.2f}x reduction via integer-DWT band codec)")

    wstep = make_wavelet_train_step(cfg, mesh, opt_cfg, sync)
    bstep = jax.jit(make_train_step(cfg, opt_cfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))

    with mesh:
        pw, ow = podded(state["params"], 2), podded_opt(state["opt"], 2)
        err = init_podded_error_feedback(state["params"], 2)
        pb, ob = state["params"], state["opt"]
        for s in range(30):
            b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
            pw, ow, err, mw = wstep(pw, ow, err, b)
            pb, ob, mb = bstep(pb, ob, b)
            if s % 5 == 0:
                print(f"step {s:3d}: compressed-sync loss {float(mw['loss']):.4f} | "
                      f"full-fidelity loss {float(mb['loss']):.4f}")
        leaf = jax.tree_util.tree_leaves(pw)[3]
        print("pod replicas bit-identical:", bool(jnp.array_equal(leaf[0], leaf[1])))


if __name__ == "__main__":
    main()
