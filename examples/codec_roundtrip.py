"""Lossless entropy-coded bitstreams over the integer wavelet bands.

    PYTHONPATH=src python examples/codec_roundtrip.py

The multiplierless DWT is the front half of a lossless coder; this demo
runs the back half (``repro.codec``): a checkpoint-like tensor and a 3-D
volume become self-describing WZRC bytes, decode bit-exactly from those
bytes alone, and beat plain zlib while doing it.
"""
import zlib

import numpy as np
import jax.numpy as jnp

from repro import kernels as K
from repro.ckpt.checkpoint import _decode, _encode
from repro.codec import container, stream


def main():
    rng = np.random.default_rng(2010)

    # --- checkpoint-like smooth tensor: wz-rice vs plain zlib -------------
    yy, xx = np.meshgrid(
        np.linspace(0, 2, 192), np.linspace(0, 2, 128), indexing="ij"
    )
    w = (np.sin(yy + xx) + 0.01 * rng.normal(size=yy.shape)).astype(np.float32)
    rice_bytes, meta = _encode(w, "wz-rice", 2)
    zlib_bytes = zlib.compress(w.tobytes(), level=1)
    restored = _decode(rice_bytes, w.shape, np.float32, "wz-rice", meta)
    print(f"smooth {w.shape} fp32 tensor: raw {w.nbytes}B")
    print(f"  plain zlib : {len(zlib_bytes)}B ({w.nbytes / len(zlib_bytes):.2f}x)")
    print(f"  wz-rice    : {len(rice_bytes)}B ({w.nbytes / len(rice_bytes):.2f}x)")
    print(f"  beats zlib by {len(zlib_bytes) / len(rice_bytes):.2f}x, "
          f"max restore err {np.max(np.abs(restored - w)):.2e} "
          f"(<= scale/2 = {meta['scale'] / 2:.2e})")

    # --- integer pyramid -> bytes -> pyramid, bit-exact -------------------
    img = jnp.asarray(rng.integers(-2000, 2000, (64, 64)), jnp.int32)
    pyr = K.dwt_fwd_2d_multi(img, levels=3, scheme="97m")
    blob = container.encode_pyramid(pyr, scheme="97m")
    dec = container.decode_pyramid(blob)  # bytes alone: self-describing
    back = container.inverse_transform(dec)
    print(f"\n2D pyramid (97m, 3 levels): {len(blob)}B, header {container.peek(blob)['shape']}")
    print("  bit-exact roundtrip?", bool(np.array_equal(np.asarray(back), np.asarray(img))))

    # --- 3-D volume, streamed per depth-slab ------------------------------
    t = np.linspace(0, 4, 24)
    vol = np.round(
        3000 * np.sin(t)[:, None, None] * np.cos(t)[None, :24, None]
        * np.sin(t + 1)[None, None, :24]
        + 20 * rng.normal(size=(24, 24, 24))
    ).astype(np.int32)
    frames = list(stream.encode_volume(vol, slab=8, levels=2, scheme="cdf53"))
    data = b"".join(frames)
    out = stream.decode_volume(data)
    print(f"\n3-D volume {vol.shape}: raw {vol.nbytes}B -> "
          f"{len(data)}B in {len(frames) - 2} slab frames "
          f"({vol.nbytes / len(data):.2f}x vs int32, "
          f"zlib gets {vol.nbytes / len(zlib.compress(vol.tobytes(), 1)):.2f}x)")
    print("  bit-exact roundtrip?", bool(np.array_equal(out, vol)))


if __name__ == "__main__":
    main()
