"""The paper's own application: line-based signal compression.

Encodes a synthetic "sound line" stream (the paper's test: lines of 256
8-bit samples) through the integer DWT -> band quantization -> zlib chain
and reports compression ratio + losslessness, using the Pallas kernel path
for the transform.

    PYTHONPATH=src python examples/wavelet_pipeline.py
"""
import zlib

import numpy as np
import jax.numpy as jnp

from repro.core import lifting as L
from repro.kernels import ops


def make_signal(n_lines: int = 64, line: int = 256, seed: int = 7) -> np.ndarray:
    """Smooth band-limited 'audio' lines + noise, 8-bit positive."""
    rng = np.random.default_rng(seed)
    t = np.arange(line)
    lines = []
    for _ in range(n_lines):
        f1, f2 = rng.uniform(0.01, 0.05), rng.uniform(0.05, 0.2)
        sig = 100 * np.sin(2 * np.pi * f1 * t + rng.uniform(0, 6)) \
            + 20 * np.sin(2 * np.pi * f2 * t) + rng.normal(0, 3, line)
        lines.append(np.clip(np.round(sig + 128), 0, 255))
    return np.stack(lines).astype(np.int32)


def main():
    x = jnp.asarray(make_signal())
    levels = 3

    # forward transform on the kernel path
    pyr = ops.dwt53_fwd(x, levels=levels)

    # entropy-code raw vs band-packed (lossless: keep full precision bands)
    raw_bytes = len(zlib.compress(np.asarray(x, np.int16).tobytes(), 6))
    packed = np.asarray(L.pack(pyr), np.int16)
    dwt_bytes = len(zlib.compress(packed.tobytes(), 6))
    print(f"lines: {x.shape}, levels: {levels}")
    print(f"zlib(raw int16)        : {raw_bytes:8d} bytes")
    print(f"zlib(DWT bands int16)  : {dwt_bytes:8d} bytes "
          f"({raw_bytes / dwt_bytes:.2f}x better)")

    # lossless reconstruction through the kernel path
    x_rec = ops.dwt53_inv(pyr)
    print("lossless reconstruction:", bool((x_rec == x).all()))

    # band energy profile (why it compresses: energy compaction)
    e_total = float(jnp.sum(x.astype(jnp.float32) ** 2))
    e_approx = float(jnp.sum(pyr.approx.astype(jnp.float32) ** 2))
    print(f"approx band holds {100 * e_approx / e_total:.1f}% of signal energy "
          f"in {pyr.approx.shape[-1]}/{x.shape[-1]} samples")


if __name__ == "__main__":
    main()
